//! The offline-material bank, sharded by **model and layer**, refilled
//! by a **fleet** of dealer links.
//!
//! Real PI fleets serve several architectures at once (Circa's per-ReLU
//! savings compose with CryptoNAS/DeepReDuce-style network-level ReLU
//! reduction), and each network concentrates its ReLUs in a few wide
//! layers. The bank therefore holds one **shard per registered model**
//! (keyed by the plan's manifest fingerprint via [`ModelRegistry`]),
//! and inside each shard *per-layer* banks: one bank of linear-precompute
//! spines ([`LinearSpine`] — masks, HE precomputes, blinds; cheap) plus
//! one bank per ReLU layer (garbled tables, label arenas, triples; the
//! expensive part), each keyed by a session **sequence number** in that
//! model's own seq namespace (its registry base seed).
//! [`MaterialPool::lease_model`] assembles a [`Session`] from the front
//! entry of every bank of that model's shard.
//!
//! Seq-addressing is what makes the shards composable: entry `(model,
//! bank, seq)` is a pure function of `(model base seed, seq, layer)`
//! under the per-layer forked session schedule
//! ([`crate::protocol::server::session_rng`]), so independently dealt
//! entries with equal seqs assemble into exactly the session a whole
//! inline deal from that session RNG would produce — bit-identical,
//! whichever dealer thread, connection, or **process** produced each
//! piece. Leases pop every bank's front at once, so a shard's fronts
//! stay seq-aligned structurally, and per-model base seeds keep two
//! shards' seq spaces from ever colliding.
//!
//! ## The fleet scheduler
//!
//! Refills come from a [`RefillSource`]: the inline deal (garble
//! in-process, from the shard's own base seed) or a **fleet** of remote
//! dealer processes ([`DealerEndpoint`]) reached over
//! [`crate::wire`]'s model-addressed layer-granular streaming round.
//! Every remote link runs the same loop: connect (before claiming, so a
//! dead dealer never strands work), claim a batch of seqs from the
//! emptiest `(model, bank)` pair, fetch, stage. Because dealing is a
//! pure function of `(base seed, seq)`, *any* link can produce *any*
//! claimed unit — which is what makes the fleet self-balancing:
//!
//! * **Claim ledger.** Every remote claim is a ticket in a
//!   [`ClaimRecord`] ledger naming its `(shard, bank, seqs, link)`. A
//!   ticket resolves exactly once — completed (units staged), abandoned
//!   (seqs back to the bank's retry list), or transferred (stolen).
//! * **Work stealing.** An idle link (no fresh deficit anywhere) steals
//!   the oldest other-link claim outstanding longer than
//!   [`PoolTuning::steal_after`]: the ledger entry is re-issued under
//!   the thief's ticket and the victim's ticket ceases to exist. The
//!   thief fetches the *same seqs*, so the staged material is
//!   bit-identical regardless of which link produced it. If the
//!   victim's fetch later completes anyway, its ticket is gone and its
//!   units are **dropped, never staged** ([`MaterialPool::late_drop_units`])
//!   — a seq can never be double-staged and a bank can never overshoot.
//! * **Reconnect with handoff.** A link whose fetch fails abandons its
//!   claimed seqs back to the bank retries (re-issued to whichever link
//!   claims next — usually a healthy one), drops its connection, and
//!   backs off exponentially (capped); repeated failures quarantine the
//!   link in ever-longer re-probe sleeps without ever blocking the rest
//!   of the fleet. Fetch poisoning is therefore **link-scoped**: one
//!   wedged dealer costs its claims a handoff, not the pool.
//! * **Traffic-adaptive weights.** Bank deficits are weighted by an
//!   EWMA of per-model lease rates
//!   ([`crate::coordinator::registry::LeaseRate`], half-life
//!   [`PoolTuning::demand_half_life`]): refill chases measured demand.
//!   Until total traffic crosses a minimum signal, the registry's
//!   static demand weights act as the cold-start prior; once live, each
//!   model's weight is its share of recent leases plus a floor so cold
//!   models keep a trickle of refill.
//!
//! Claim accounting is exact **per shard**: a bank's staged + in-flight
//! entries never exceed `target`, so racing links cannot overshoot any
//! bank and a hot model cannot starve accounting of a cold one. Remote
//! units are fingerprint-checked at staging: a `LayerBatch`/`Spine`
//! tagged with another model's fingerprint is dropped and counted
//! ([`MaterialPool::fingerprint_drops`]), never banked into the wrong
//! shard. [`MaterialPool::wait_ready`] is stop-aware, so a fleet that
//! never connects cannot hang warmup or shutdown forever.

use super::metrics::Metrics;
use super::registry::{LeaseRate, ModelRegistry};
use crate::protocol::client::ClientNet;
use crate::protocol::offline::{ClientReluMaterial, ServerReluMaterial};
use crate::protocol::server::{
    assemble_session, deal_relu_layer_mt, deal_spine, offline_network_mt, session_rng,
    LinearSpine, NetworkPlan, ServerNet,
};
use crate::util::error::Result;
use crate::util::{Rng, Timer};
use crate::wire::dealer::RemoteDealer;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One ready-to-serve inference session.
pub struct Session {
    pub client: ClientNet,
    pub server: ServerNet,
    pub offline_bytes: u64,
}

impl Session {
    /// ReLUs of offline material in this session (the deal-throughput
    /// denominator).
    pub fn n_relus(&self) -> usize {
        self.server.n_relus()
    }
}

/// Outcome of [`MaterialPool::lease_model`]: the session plus where it
/// came from. A dry lease carries the inline-deal latency so the caller
/// can surface it as tail latency (the serving metrics record it).
pub struct Lease {
    pub session: Session,
    pub was_dry: bool,
    /// Microseconds spent dealing inline (0 for banked sessions).
    pub deal_us: u64,
}

type ReluEntry = (ClientReluMaterial, ServerReluMaterial);

/// Count keys `head, head+1, …` present in `m` (the bank's ready run).
fn contiguous_from<V>(m: &BTreeMap<u64, V>, head: u64) -> usize {
    let mut n = 0u64;
    for (&k, _) in m.range(head..) {
        if k != head + n {
            break;
        }
        n += 1;
    }
    n as usize
}

/// One model's layer-sharded bank. Bank index 0 holds linear spines;
/// bank `1 + li` holds ReLU layer `li`. Entries are staged in
/// `BTreeMap`s keyed by seq because completions can land out of order
/// (racing dealers, retried claims, stolen claims); contiguity from
/// `head` is what counts as ready.
struct Bank {
    /// Seq of the next session [`MaterialPool::lease_model`] will
    /// assemble.
    head: u64,
    spines: BTreeMap<u64, LinearSpine>,
    relus: Vec<BTreeMap<u64, ReluEntry>>,
    /// Next fresh seq each bank hands out to a dealer claim.
    next_claim: Vec<u64>,
    /// Claims handed out but not yet completed or abandoned. Every
    /// in-flight unit is owned by exactly one claim — an inline
    /// claimer's loop iteration or one live remote ticket.
    in_flight: Vec<usize>,
    /// Abandoned claims, re-dealt before fresh seqs are claimed.
    retries: Vec<Vec<u64>>,
}

impl Bank {
    fn new(n_relu: usize) -> Self {
        Bank {
            head: 0,
            spines: BTreeMap::new(),
            relus: (0..n_relu).map(|_| BTreeMap::new()).collect(),
            next_claim: vec![0; 1 + n_relu],
            in_flight: vec![0; 1 + n_relu],
            retries: (0..n_relu + 1).map(|_| Vec::new()).collect(),
        }
    }

    fn n_banks(&self) -> usize {
        1 + self.relus.len()
    }

    fn staged(&self, b: usize) -> usize {
        if b == 0 {
            self.spines.len()
        } else {
            self.relus[b - 1].len()
        }
    }

    /// Entries committed against `target`: staged plus in-flight claims
    /// (abandoned retries are uncommitted — they need re-dealing).
    fn supply(&self, b: usize) -> usize {
        self.staged(b) + self.in_flight[b]
    }

    /// Claim up to `max` seqs from bank `b`, retries first (caller has
    /// already picked `b` by weighted deficit — claim accounting is what
    /// makes overshoot impossible).
    fn claim(&mut self, b: usize, n: usize) -> Vec<u64> {
        (0..n)
            .map(|_| {
                self.in_flight[b] += 1;
                self.retries[b].pop().unwrap_or_else(|| {
                    let s = self.next_claim[b];
                    self.next_claim[b] += 1;
                    s
                })
            })
            .collect()
    }

    fn abandon(&mut self, b: usize, seqs: &[u64]) {
        self.in_flight[b] -= seqs.len();
        self.retries[b].extend_from_slice(seqs);
    }

    fn complete_spine(&mut self, seq: u64, spine: LinearSpine) {
        self.in_flight[0] -= 1;
        self.spines.insert(seq, spine);
    }

    fn complete_relu(&mut self, li: usize, seq: u64, entry: ReluEntry) {
        self.in_flight[1 + li] -= 1;
        self.relus[li].insert(seq, entry);
    }

    /// Sessions assemblable right now: the shortest contiguous run from
    /// `head` across all banks.
    fn ready_run(&self) -> usize {
        let mut run = contiguous_from(&self.spines, self.head);
        for m in &self.relus {
            run = run.min(contiguous_from(m, self.head));
        }
        run
    }

    /// Pop the front entry of every bank (requires `ready_run() >= 1`).
    /// Popping all banks at once is what keeps the fronts seq-aligned.
    fn pop_head(&mut self) -> (LinearSpine, Vec<ReluEntry>) {
        let head = self.head;
        let spine = self.spines.remove(&head).expect("ready head spine");
        let relus: Vec<ReluEntry> = self
            .relus
            .iter_mut()
            .map(|m| m.remove(&head).expect("ready head layer"))
            .collect();
        self.head += 1;
        (spine, relus)
    }

    fn depths(&self) -> Vec<usize> {
        (0..self.n_banks()).map(|b| self.staged(b)).collect()
    }
}

/// One registered model's shard of the pool.
struct Shard {
    fingerprint: u64,
    plan: Arc<NetworkPlan>,
    /// This model's seq-addressed dealing namespace (inline refills and
    /// the shape the remote dealer must reproduce from *its* registry).
    base_seed: u64,
    /// Static refill-priority weight — the cold-start prior before any
    /// lease traffic has been observed.
    demand: f64,
    /// EWMA of this model's lease rate (the live demand signal).
    lease_rate: LeaseRate,
    bank: Bank,
    /// High-water mark of `head + ready_run()` — sessions ever made
    /// assemblable from this shard.
    high_water: u64,
}

/// One outstanding remote claim (ledger entry). The ticket id is the
/// map key; the record names what was claimed and which link holds it.
struct ClaimRecord {
    si: usize,
    bank: usize,
    seqs: Vec<u64>,
    link: usize,
    issued_at: Instant,
}

/// Per-link health, as seen by [`MaterialPool::link_states`].
struct LinkState {
    label: String,
    connected: bool,
}

/// Everything behind the pool's one mutex: shards, the remote-claim
/// ledger, link health, and the fleet counters.
struct PoolState {
    shards: Vec<Shard>,
    claims: BTreeMap<u64, ClaimRecord>,
    next_ticket: u64,
    links: Vec<LinkState>,
    steals: u64,
    /// Seqs put back for another link to produce — by steal or by
    /// failure handoff.
    reissued_seqs: u64,
    /// Units delivered by a link whose ticket had been stolen: dropped,
    /// never staged (the thief's copy owns the accounting).
    late_drop_units: u64,
}

struct Shared {
    state: Mutex<PoolState>,
    ready: Condvar,
    refill: Condvar,
    stop: AtomicBool,
    dry_leases: AtomicU64,
    /// Remote units dropped because their fingerprint tag named another
    /// model (never banked into the wrong shard).
    fp_drops: AtomicU64,
}

/// Below this total EWMA score the pool has no meaningful traffic
/// signal and falls back to the registry's static demand priors.
const MIN_TRAFFIC_SIGNAL: f64 = 1.0;
/// Additive weight floor so a currently-cold model keeps a trickle of
/// refill (it must have warm banks by the time traffic returns).
const WEIGHT_FLOOR: f64 = 0.05;

/// Per-shard effective refill weights at `now`: lease-rate shares once
/// there is traffic, static demand priors before.
fn effective_weights(shards: &[Shard], now: Instant) -> Vec<f64> {
    let scores: Vec<f64> = shards.iter().map(|s| s.lease_rate.score(now)).collect();
    let total: f64 = scores.iter().sum();
    if total < MIN_TRAFFIC_SIGNAL {
        return shards.iter().map(|s| s.demand).collect();
    }
    scores.iter().map(|s| s / total + WEIGHT_FLOOR).collect()
}

/// Pick the `(shard, bank)` pair with the largest weighted deficit and
/// claim up to `max` seqs from it. `None` when every bank of every
/// shard is at target.
fn claim_weighted_emptiest(
    shards: &mut [Shard],
    target: usize,
    max: usize,
    now: Instant,
) -> Option<(usize, usize, Vec<u64>)> {
    let weights = effective_weights(shards, now);
    let mut best: Option<(usize, usize, usize)> = None;
    let mut best_w = 0.0f64;
    for ((si, sh), w) in shards.iter().enumerate().zip(weights.iter()) {
        for b in 0..sh.bank.n_banks() {
            let deficit = target.saturating_sub(sh.bank.supply(b));
            if deficit == 0 {
                continue;
            }
            let dw = deficit as f64 * w;
            if dw > best_w {
                best_w = dw;
                best = Some((si, b, deficit));
            }
        }
    }
    let (si, b, deficit) = best?;
    let n = deficit.min(max.max(1));
    let seqs = shards[si].bank.claim(b, n);
    Some((si, b, seqs))
}

/// Update a shard's produced high-water mark and its metrics depth gauge
/// after completions land (caller holds the state lock).
fn publish_progress(shards: &mut [Shard], si: usize, metrics: &Option<Arc<Metrics>>) {
    let sh = &mut shards[si];
    let high_water = sh.bank.head + sh.bank.ready_run() as u64;
    sh.high_water = sh.high_water.max(high_water);
    if let Some(m) = metrics {
        m.set_bank_depths(
            sh.fingerprint,
            sh.bank.depths().iter().map(|&d| d as u64).collect(),
        );
    }
}

/// Cross-check that every ReLU layer's `r_out` chain binds to the
/// spine's mask chain (`truncate(r_out[li]) == spine.slots[li+1].r`).
/// Seq-aligned pops make mixed-seq assembly structurally impossible
/// *within* one shard, but a remote dealer restarted with a different
/// base seed mid-stream would fill later claims from a different RNG
/// universe — this O(#ReLU) check catches that before a silently-wrong
/// session is served.
fn spine_binds_layers(plan: &NetworkPlan, spine: &LinearSpine, relus: &[ReluEntry]) -> bool {
    for (li, (cm, _)) in relus.iter().enumerate() {
        let rescale = plan.rescale_of(li);
        let want = &spine.slots[li + 1].r;
        if cm.r_out.len() != want.len() {
            return false;
        }
        let bound = cm
            .r_out
            .iter()
            .zip(want.iter())
            .all(|(&y, &m)| crate::nn::layers::truncate_share_local(y, rescale, true) == m);
        if !bound {
            return false;
        }
    }
    true
}

/// One member of the refill fleet: a label (for logs and per-link
/// metrics rows) and a connect closure that establishes a fresh
/// [`RemoteDealer`] link. The closure is re-invoked after every
/// transport failure, so it must be safe to call repeatedly.
#[derive(Clone)]
pub struct DealerEndpoint {
    pub label: String,
    pub connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync>,
}

impl DealerEndpoint {
    pub fn new(
        label: impl Into<String>,
        connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync>,
    ) -> Self {
        Self { label: label.into(), connect }
    }

    /// A TCP endpoint at `addr`, authenticated with `psk` when set
    /// ([`RemoteDealer::connect_tcp_psk`]). The label is the address.
    pub fn tcp(addr: &str, registry: Arc<ModelRegistry>, psk: Option<[u8; 16]>) -> Self {
        let addr = addr.to_string();
        let label = addr.clone();
        let connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync> =
            Arc::new(move || RemoteDealer::connect_tcp_psk(&addr, registry.clone(), psk));
        Self { label, connect }
    }
}

/// Where dealer threads get their material.
pub enum RefillSource {
    /// Deal layer entries inline in local dealer threads (the default).
    Inline,
    /// Stream per-layer material from a fleet of remote dealer
    /// processes over the model-addressed layer-granular wire round.
    /// `batch` caps entries per round trip. All endpoints must reach
    /// dealers sharing one registry (per-model base seeds) —
    /// seq-addressing makes their answers mutually consistent, which is
    /// what lets the pool partition, steal, and re-issue claims across
    /// them freely.
    Remote {
        endpoints: Vec<DealerEndpoint>,
        batch: usize,
    },
}

impl RefillSource {
    /// A remote fleet over `endpoints`.
    pub fn remote(endpoints: Vec<DealerEndpoint>, batch: usize) -> Self {
        RefillSource::Remote { endpoints, batch }
    }

    /// A single-endpoint fleet from a bare connect closure (the
    /// pre-fleet call shape; the endpoint is labeled `"dealer"`).
    pub fn remote_single(
        connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync>,
        batch: usize,
    ) -> Self {
        RefillSource::Remote { endpoints: vec![DealerEndpoint::new("dealer", connect)], batch }
    }
}

/// Fleet-scheduler knobs. Defaults suit LAN dealers; tests shrink them.
#[derive(Clone, Copy, Debug)]
pub struct PoolTuning {
    /// Age after which an idle link may steal another link's
    /// outstanding claim.
    pub steal_after: Duration,
    /// Half-life of the per-model lease-rate EWMA behind the adaptive
    /// refill weights.
    pub demand_half_life: Duration,
}

impl Default for PoolTuning {
    fn default() -> Self {
        Self {
            steal_after: Duration::from_millis(1000),
            demand_half_life: Duration::from_secs(10),
        }
    }
}

enum Fetched {
    Spines(Vec<(u64, u64, LinearSpine)>),
    Layers(Vec<(u64, u64, ClientReluMaterial, ServerReluMaterial)>),
}

/// Exponential failure backoff, stop-aware (sleeps in small slices so
/// shutdown never waits out a quarantined link's full backoff).
fn backoff_sleep(shared: &Shared, failures: u64) {
    let ms = 50u64.saturating_mul(1 << failures.saturating_sub(1).min(7)).min(5_000);
    let mut slept = 0u64;
    while slept < ms {
        if shared.stop.load(Ordering::Relaxed) {
            return;
        }
        let step = 25u64.min(ms - slept);
        std::thread::sleep(Duration::from_millis(step));
        slept += step;
    }
}

/// Claim work for remote link `link`: a fresh weighted-deficit claim if
/// one exists, else the oldest other-link claim stale past
/// `steal_after` (ownership transfer — the victim's ticket ceases to
/// exist), else wait. Returns `None` on stop.
fn acquire_work(
    shared: &Shared,
    link: usize,
    target: usize,
    batch: usize,
    steal_after: Duration,
    metrics: &Option<Arc<Metrics>>,
) -> Option<(u64, usize, usize, Vec<u64>, u64)> {
    let mut state = shared.state.lock().unwrap();
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return None;
        }
        let now = Instant::now();
        let st = &mut *state;
        if let Some((si, bank, seqs)) = claim_weighted_emptiest(&mut st.shards, target, batch, now)
        {
            let fp = st.shards[si].fingerprint;
            let ticket = st.next_ticket;
            st.next_ticket += 1;
            let rec = ClaimRecord { si, bank, seqs: seqs.clone(), link, issued_at: now };
            st.claims.insert(ticket, rec);
            return Some((ticket, si, bank, seqs, fp));
        }
        let victim = st
            .claims
            .iter()
            .filter(|(_, r)| r.link != link && now.duration_since(r.issued_at) >= steal_after)
            .min_by_key(|(_, r)| r.issued_at)
            .map(|(&t, _)| t);
        if let Some(t) = victim {
            let rec = st.claims.remove(&t).expect("victim ticket present");
            st.steals += 1;
            st.reissued_seqs += rec.seqs.len() as u64;
            if let Some(m) = metrics {
                m.record_link_steal(link, rec.link);
            }
            let (si, bank) = (rec.si, rec.bank);
            let fp = st.shards[si].fingerprint;
            let seqs = rec.seqs.clone();
            let ticket = st.next_ticket;
            st.next_ticket += 1;
            let rec = ClaimRecord { si, bank, seqs: rec.seqs, link, issued_at: now };
            st.claims.insert(ticket, rec);
            return Some((ticket, si, bank, seqs, fp));
        }
        // Nothing claimable yet: wake on refill demand, or after
        // steal_after to re-scan for newly stale claims.
        let (g, _) = shared.refill.wait_timeout(state, steal_after).unwrap();
        state = g;
    }
}

/// Static per-link parameters of [`run_link`].
struct LinkCtx {
    link: usize,
    label: String,
    target: usize,
    batch: usize,
    steal_after: Duration,
}

/// One remote fleet link: connect → claim → fetch → stage, forever.
fn run_link(
    shared: Arc<Shared>,
    endpoint: DealerEndpoint,
    ctx: LinkCtx,
    metrics: Option<Arc<Metrics>>,
) {
    let LinkCtx { link, label, target, batch, steal_after } = ctx;
    let mut conn: Option<RemoteDealer> = None;
    // Connect + fetch failures share one counter, reset only on a
    // successful fetch — a dealer that handshakes but fails every fetch
    // still gets surfaced (and backed off from).
    let mut failures = 0u64;
    // Rounds that delivered fingerprint-mismatched units (throttles the
    // mistagging-dealer log like `failures` throttles transport errors).
    let mut drop_rounds = 0u64;
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return;
        }
        // Connect before claiming: a link that cannot reach its dealer
        // must not strand claimed seqs while it retries.
        if conn.is_none() {
            match (endpoint.connect)() {
                Ok(dealer) => {
                    if failures > 0 {
                        if let Some(m) = &metrics {
                            m.record_link_reconnect(link);
                        }
                    }
                    shared.state.lock().unwrap().links[link].connected = true;
                    conn = Some(dealer);
                }
                Err(e) => {
                    failures += 1;
                    if let Some(m) = &metrics {
                        m.record_link_failure(link);
                    }
                    shared.state.lock().unwrap().links[link].connected = false;
                    if failures.is_power_of_two() {
                        eprintln!("[pool {label}] dealer connect failed ({failures}x): {e}");
                    }
                    backoff_sleep(&shared, failures);
                    continue;
                }
            }
        }
        let Some((ticket, si, bank_idx, seqs, fp)) =
            acquire_work(&shared, link, target, batch, steal_after, &metrics)
        else {
            return;
        };
        let dealer = conn.as_mut().expect("link connected before claiming");
        let before = dealer.bytes_received();
        let t = Timer::new();
        let fetched: Result<Fetched> = if bank_idx == 0 {
            dealer.fetch_spines(fp, &seqs).map(Fetched::Spines)
        } else {
            dealer.fetch_layers(fp, bank_idx - 1, &seqs).map(Fetched::Layers)
        };
        let fetch_us = t.elapsed_us();
        let wire_bytes = dealer.bytes_received() - before;
        match fetched {
            Ok(units) => {
                failures = 0;
                let n_units = match &units {
                    Fetched::Spines(v) => v.len(),
                    Fetched::Layers(v) => v.len(),
                } as u64;
                let mut state = shared.state.lock().unwrap();
                let Some(rec) = state.claims.remove(&ticket) else {
                    // This claim was stolen while the fetch was in
                    // flight; the thief's ticket owns the seqs now.
                    // Staging these units would double-bank them, so
                    // drop the whole delivery (bit-identity means
                    // nothing is lost — the thief stages equal bytes).
                    state.late_drop_units += n_units;
                    if let Some(m) = &metrics {
                        m.record_link_late_drop(link, n_units);
                    }
                    continue;
                };
                // Stage fingerprint-matching units; drop + count +
                // re-claim the rest — a unit tagged for model B can
                // never land in model A's shard.
                let st = &mut *state;
                let mut answered: Vec<u64> = Vec::with_capacity(n_units as usize);
                let mut dropped: Vec<u64> = Vec::new();
                let mut staged = 0u64;
                let mut staged_spines = 0u64;
                match units {
                    Fetched::Spines(v) => {
                        for (ufp, seq, spine) in v {
                            answered.push(seq);
                            if ufp == fp {
                                staged += 1;
                                staged_spines += 1;
                                st.shards[si].bank.complete_spine(seq, spine);
                            } else {
                                dropped.push(seq);
                            }
                        }
                    }
                    Fetched::Layers(v) => {
                        for (ufp, seq, cm, sm) in v {
                            answered.push(seq);
                            if ufp == fp {
                                staged += 1;
                                st.shards[si].bank.complete_relu(bank_idx - 1, seq, (cm, sm));
                            } else {
                                dropped.push(seq);
                            }
                        }
                    }
                }
                // A short answer (dealer bug) must not leak in-flight
                // accounting: claimed-but-unanswered seqs go back to
                // the retry list so the ledger stays exact.
                let missing: Vec<u64> =
                    rec.seqs.iter().copied().filter(|s| !answered.contains(s)).collect();
                if !dropped.is_empty() {
                    shared.fp_drops.fetch_add(dropped.len() as u64, Ordering::Relaxed);
                    if let Some(m) = &metrics {
                        m.fp_mismatch_drops.fetch_add(dropped.len() as u64, Ordering::Relaxed);
                    }
                    st.shards[si].bank.abandon(bank_idx, &dropped);
                }
                if !missing.is_empty() {
                    st.shards[si].bank.abandon(bank_idx, &missing);
                }
                // Only material that actually staged counts toward the
                // model's refill row — a mistagging dealer must not
                // make a starved model look well fed. Recorded under
                // the state lock so a wait_ready waiter can never see
                // the staging without its counters.
                if let Some(m) = &metrics {
                    m.record_layer_refill(fp, fetch_us.max(1), wire_bytes, staged, staged_spines);
                    m.record_link_fetch(link, fetch_us.max(1), wire_bytes, staged);
                }
                publish_progress(&mut st.shards, si, &metrics);
                drop(state);
                shared.ready.notify_all();
                if !dropped.is_empty() || !missing.is_empty() {
                    shared.refill.notify_all();
                }
                if !dropped.is_empty() {
                    // A mistagging dealer is a failure mode, not a hot
                    // path: surface it (throttled, outside the lock)
                    // and slow the re-claim so the abandoned seqs don't
                    // spin.
                    drop_rounds += 1;
                    if drop_rounds.is_power_of_two() {
                        eprintln!(
                            "[pool {label}] dropped {} unit(s) tagged for another model \
                             (wanted {fp:#018x}; {drop_rounds} rounds affected)",
                            dropped.len()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
            Err(e) => {
                // Transport failure: hand the claim off (abandoned seqs
                // are re-issued to whichever link claims next), drop
                // the connection, quarantine with backoff. The failure
                // is link-scoped by construction — no shared state is
                // poisoned.
                failures += 1;
                if let Some(m) = &metrics {
                    m.record_link_failure(link);
                }
                if failures.is_power_of_two() {
                    eprintln!("[pool {label}] layer fetch failed ({failures}x): {e}");
                }
                let mut state = shared.state.lock().unwrap();
                if let Some(rec) = state.claims.remove(&ticket) {
                    state.reissued_seqs += rec.seqs.len() as u64;
                    let st = &mut *state;
                    st.shards[rec.si].bank.abandon(rec.bank, &rec.seqs);
                }
                // (A missing ticket means the claim was stolen
                // mid-fetch — the thief owns the seqs; nothing to hand
                // off.)
                state.links[link].connected = false;
                drop(state);
                shared.refill.notify_all();
                conn = None;
                backoff_sleep(&shared, failures);
            }
        }
    }
}

/// One inline dealer thread: claim one seq, garble it locally, stage.
/// Inline claims need no ledger tickets — there is no transport to
/// fail, so a claim always completes.
fn run_inline(
    shared: Arc<Shared>,
    target: usize,
    deal_threads: usize,
    metrics: Option<Arc<Metrics>>,
) {
    loop {
        let (si, bank_idx, seq, fp, plan, base_seed) = {
            let mut state = shared.state.lock().unwrap();
            loop {
                if shared.stop.load(Ordering::Relaxed) {
                    return;
                }
                let now = Instant::now();
                let st = &mut *state;
                match claim_weighted_emptiest(&mut st.shards, target, 1, now) {
                    Some((si, b, seqs)) => {
                        let sh = &st.shards[si];
                        break (si, b, seqs[0], sh.fingerprint, sh.plan.clone(), sh.base_seed);
                    }
                    None => state = shared.refill.wait(state).unwrap(),
                }
            }
        };
        // Deal the claimed entry outside the lock (garbling is slow);
        // the deal itself fans out over deal_threads.
        if bank_idx == 0 {
            let spine = deal_spine(&plan, &mut session_rng(base_seed, seq));
            let mut state = shared.state.lock().unwrap();
            let st = &mut *state;
            st.shards[si].bank.complete_spine(seq, spine);
            publish_progress(&mut st.shards, si, &metrics);
        } else {
            let li = bank_idx - 1;
            let t = Timer::new();
            let (cm, sm) =
                deal_relu_layer_mt(&plan, &mut session_rng(base_seed, seq), li, deal_threads);
            if let Some(m) = &metrics {
                m.record_deal(fp, cm.n() as u64, t.elapsed_us());
            }
            let mut state = shared.state.lock().unwrap();
            let st = &mut *state;
            st.shards[si].bank.complete_relu(li, seq, (cm, sm));
            publish_progress(&mut st.shards, si, &metrics);
        }
        shared.ready.notify_all();
    }
}

/// Material bank with background dealer threads, sharded per registered
/// model.
pub struct MaterialPool {
    registry: Arc<ModelRegistry>,
    shared: Arc<Shared>,
    target: usize,
    deal_threads: usize,
    metrics: Option<Arc<Metrics>>,
    dealers: Vec<JoinHandle<()>>,
}

impl MaterialPool {
    /// Spawn a single-model pool refilling every bank toward `target`
    /// with `n_dealers` inline dealer threads. The model's seq namespace
    /// is exactly `seed` (dealt bytes identical to the pre-registry
    /// single-model pool for the same `(seed, plan)`).
    pub fn start(plan: Arc<NetworkPlan>, target: usize, n_dealers: usize, seed: u64) -> Self {
        Self::start_with_source(plan, target, n_dealers, seed, RefillSource::Inline, None, 1)
    }

    /// Single-model pool with an explicit [`RefillSource`] (a registry of
    /// one plan under base seed `seed`). See [`Self::start_multi`].
    pub fn start_with_source(
        plan: Arc<NetworkPlan>,
        target: usize,
        n_dealers: usize,
        seed: u64,
        source: RefillSource,
        metrics: Option<Arc<Metrics>>,
        deal_threads: usize,
    ) -> Self {
        Self::start_multi(
            ModelRegistry::single(plan, seed),
            target,
            n_dealers,
            source,
            metrics,
            deal_threads,
        )
    }

    /// [`Self::start_multi_tuned`] with default [`PoolTuning`].
    pub fn start_multi(
        registry: Arc<ModelRegistry>,
        target: usize,
        n_dealers: usize,
        source: RefillSource,
        metrics: Option<Arc<Metrics>>,
        deal_threads: usize,
    ) -> Self {
        Self::start_multi_tuned(
            registry,
            target,
            n_dealers,
            source,
            metrics,
            deal_threads,
            PoolTuning::default(),
        )
    }

    /// Spawn a pool with one shard per model in `registry`. For an
    /// inline source, `n_dealers` local dealer threads refill the
    /// banks; for a remote source the pool runs `max(n_dealers,
    /// #endpoints)` fleet links (endpoints round-robined when links
    /// outnumber them). When `metrics` is given, refills record their
    /// latency and bytes-on-wire per model *and* per link, inline deals
    /// record their ReLU throughput, and per-bank depth gauges plus the
    /// EWMA demand gauges are published. `deal_threads` splits each
    /// inline (and dry-lease) deal's garble and triple columns across
    /// threads — the column-wise RNG schedule keeps the material
    /// bit-identical for every value.
    pub fn start_multi_tuned(
        registry: Arc<ModelRegistry>,
        target: usize,
        n_dealers: usize,
        source: RefillSource,
        metrics: Option<Arc<Metrics>>,
        deal_threads: usize,
        tuning: PoolTuning,
    ) -> Self {
        assert!(!registry.is_empty(), "pool needs at least one registered model");
        let deal_threads = deal_threads.max(1);
        let shards: Vec<Shard> = registry
            .entries()
            .iter()
            .map(|e| Shard {
                fingerprint: e.fingerprint(),
                plan: e.plan.clone(),
                base_seed: e.base_seed,
                demand: e.demand,
                lease_rate: LeaseRate::new(tuning.demand_half_life),
                bank: Bank::new(e.plan.n_relu_layers()),
                high_water: 0,
            })
            .collect();
        let (link_labels, remote) = match source {
            RefillSource::Inline => (Vec::new(), None),
            RefillSource::Remote { endpoints, batch } => {
                assert!(!endpoints.is_empty(), "remote refill needs at least one endpoint");
                let n_links = n_dealers.max(1).max(endpoints.len());
                let labels: Vec<String> = (0..n_links)
                    .map(|i| {
                        let ep = &endpoints[i % endpoints.len()];
                        if n_links > endpoints.len() {
                            format!("{}#{i}", ep.label)
                        } else {
                            ep.label.clone()
                        }
                    })
                    .collect();
                (labels, Some((endpoints, batch.max(1))))
            }
        };
        if let Some(m) = &metrics {
            if !link_labels.is_empty() {
                m.register_links(&link_labels);
            }
        }
        let links: Vec<LinkState> = link_labels
            .iter()
            .map(|l| LinkState { label: l.clone(), connected: false })
            .collect();
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                shards,
                claims: BTreeMap::new(),
                next_ticket: 0,
                links,
                steals: 0,
                reissued_seqs: 0,
                late_drop_units: 0,
            }),
            ready: Condvar::new(),
            refill: Condvar::new(),
            stop: AtomicBool::new(false),
            dry_leases: AtomicU64::new(0),
            fp_drops: AtomicU64::new(0),
        });
        let mut dealers = Vec::new();
        match remote {
            None => {
                for _ in 0..n_dealers.max(1) {
                    let shared = shared.clone();
                    let metrics = metrics.clone();
                    dealers.push(std::thread::spawn(move || {
                        run_inline(shared, target, deal_threads, metrics)
                    }));
                }
            }
            Some((endpoints, batch)) => {
                for (i, label) in link_labels.iter().enumerate() {
                    let shared = shared.clone();
                    let metrics = metrics.clone();
                    let ep = endpoints[i % endpoints.len()].clone();
                    let ctx = LinkCtx {
                        link: i,
                        label: label.clone(),
                        target,
                        batch,
                        steal_after: tuning.steal_after,
                    };
                    dealers.push(std::thread::spawn(move || {
                        run_link(shared, ep, ctx, metrics)
                    }));
                }
            }
        }
        Self { registry, shared, target, deal_threads, metrics, dealers }
    }

    /// The pool's model registry (shared with the service and the remote
    /// connect closures).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    fn shard_index(&self, model: u64) -> usize {
        self.registry
            .index_of(model)
            .unwrap_or_else(|| panic!("model {model:#018x} not registered with this pool"))
    }

    /// [`Self::lease_model`] for the first registered model (the
    /// single-model convenience).
    pub fn lease(&self, rng: &mut Rng) -> Lease {
        self.lease_model(self.registry.entries()[0].fingerprint(), rng)
    }

    /// Lease a session of model `model`: assemble one from its shard's
    /// front entries, or deal inline when no full session is ready. The
    /// dry path measures the inline deal so callers can record it into
    /// the serving [`super::Metrics`] — pool-dry tail latency is exactly
    /// what a deployment's offline-throughput shortfall looks like.
    /// Every lease also bumps the model's [`LeaseRate`] EWMA — the
    /// traffic signal behind the adaptive refill weights. Panics if
    /// `model` is not registered (the service validates at submission).
    pub fn lease_model(&self, model: u64, rng: &mut Rng) -> Lease {
        let si = self.shard_index(model);
        let popped = {
            let mut state = self.shared.state.lock().unwrap();
            let now = Instant::now();
            state.shards[si].lease_rate.bump(now);
            if let Some(m) = &self.metrics {
                let weights = effective_weights(&state.shards, now);
                let score = state.shards[si].lease_rate.score(now);
                m.set_demand(model, score, weights[si]);
            }
            if state.shards[si].bank.ready_run() >= 1 {
                let entry = state.shards[si].bank.pop_head();
                // Keep the depth gauge honest while leases drain the
                // banks (the produced high-water update inside is a
                // monotone no-op on pops).
                publish_progress(&mut state.shards, si, &self.metrics);
                Some(entry)
            } else {
                None
            }
        };
        let plan = self.registry.entries()[si].plan.clone();
        if let Some((spine, relus)) = popped {
            self.shared.refill.notify_all();
            if spine_binds_layers(&plan, &spine, &relus) {
                let (client, server, offline_bytes) = assemble_session(&plan, spine, relus);
                return Lease {
                    session: Session { client, server, offline_bytes },
                    was_dry: false,
                    deal_us: 0,
                };
            }
            // Mixed-universe material (e.g. a remote dealer restarted
            // with a different base seed mid-stream): refuse to serve
            // it, surface loudly, and fall through to a dry deal.
            eprintln!(
                "[pool] discarding banked session of model {model:#018x}: layer material \
                 does not bind to its spine (dealer base seed changed mid-stream?)"
            );
        }
        // Dry: prepare inline, and time it.
        self.shared.dry_leases.fetch_add(1, Ordering::Relaxed);
        let t = Timer::new();
        let (client, server, offline_bytes) =
            offline_network_mt(&plan, rng, self.deal_threads);
        Lease {
            session: Session { client, server, offline_bytes },
            was_dry: true,
            deal_us: t.elapsed_us(),
        }
    }

    /// Block until at least `n` full sessions are assemblable for
    /// **every** registered model (warmup). Stop-aware: returns early
    /// once [`Self::stop`]/[`Self::shutdown`] is called, so a fleet
    /// that never connects cannot hang warmup forever.
    pub fn wait_ready(&self, n: usize) {
        let want = n.min(self.target);
        let mut state = self.shared.state.lock().unwrap();
        while state.shards.iter().any(|s| s.bank.ready_run() < want)
            && !self.shared.stop.load(Ordering::Relaxed)
        {
            state = self.shared.ready.wait(state).unwrap();
        }
    }

    /// Full sessions assemblable right now for every model (the minimum
    /// across shards; single-model pools read as before).
    pub fn banked(&self) -> usize {
        let state = self.shared.state.lock().unwrap();
        state.shards.iter().map(|s| s.bank.ready_run()).min().unwrap_or(0)
    }

    /// Full sessions assemblable right now for one model.
    pub fn banked_model(&self, model: u64) -> usize {
        let si = self.shard_index(model);
        self.shared.state.lock().unwrap().shards[si].bank.ready_run()
    }

    /// Staged entries per bank of the **first registered model** (index
    /// 0 = linear spines, `1 + li` = ReLU layer `li`) — the single-model
    /// convenience; see [`Self::bank_depths_model`].
    pub fn bank_depths(&self) -> Vec<usize> {
        self.bank_depths_model(self.registry.entries()[0].fingerprint())
    }

    /// Staged entries per bank of one model's shard.
    pub fn bank_depths_model(&self, model: u64) -> Vec<usize> {
        let si = self.shard_index(model);
        self.shared.state.lock().unwrap().shards[si].bank.depths()
    }

    pub fn dry_leases(&self) -> u64 {
        self.shared.dry_leases.load(Ordering::Relaxed)
    }

    /// Remote units dropped at staging because their fingerprint tag
    /// named another model.
    pub fn fingerprint_drops(&self) -> u64 {
        self.shared.fp_drops.load(Ordering::Relaxed)
    }

    /// Claims stolen by idle links from stale links.
    pub fn steals(&self) -> u64 {
        self.shared.state.lock().unwrap().steals
    }

    /// Seqs handed back for another link to produce (by steal or by
    /// failure handoff).
    pub fn reissued_seqs(&self) -> u64 {
        self.shared.state.lock().unwrap().reissued_seqs
    }

    /// Units delivered under a stolen (dead) ticket and dropped, never
    /// staged.
    pub fn late_drop_units(&self) -> u64 {
        self.shared.state.lock().unwrap().late_drop_units
    }

    /// Outstanding remote-claim ledger entries: `(records, total seqs)`.
    pub fn outstanding_claims(&self) -> (usize, usize) {
        let state = self.shared.state.lock().unwrap();
        (state.claims.len(), state.claims.values().map(|r| r.seqs.len()).sum())
    }

    /// In-flight claimed units summed across every bank of every shard.
    pub fn in_flight_total(&self) -> usize {
        let state = self.shared.state.lock().unwrap();
        state.shards.iter().map(|s| s.bank.in_flight.iter().sum::<usize>()).sum()
    }

    /// Current effective refill weights, `(fingerprint, weight)` in
    /// registration order (demand priors until traffic crosses the
    /// minimum signal).
    pub fn effective_weights(&self) -> Vec<(u64, f64)> {
        let state = self.shared.state.lock().unwrap();
        let now = Instant::now();
        let w = effective_weights(&state.shards, now);
        state.shards.iter().zip(w).map(|(s, w)| (s.fingerprint, w)).collect()
    }

    /// Fleet link health: `(label, connected)` per link (empty for
    /// inline pools).
    pub fn link_states(&self) -> Vec<(String, bool)> {
        let state = self.shared.state.lock().unwrap();
        state.links.iter().map(|l| (l.label.clone(), l.connected)).collect()
    }

    /// Sessions ever made assemblable from the banks, summed across
    /// shards (high-water mark).
    pub fn produced(&self) -> u64 {
        self.shared.state.lock().unwrap().shards.iter().map(|s| s.high_water).sum()
    }

    /// Sessions ever made assemblable for one model.
    pub fn produced_model(&self, model: u64) -> u64 {
        let si = self.shard_index(model);
        self.shared.state.lock().unwrap().shards[si].high_water
    }

    /// Signal dealers and waiters to stop, without joining. The lock is
    /// held across the notify so a waiter between its predicate check
    /// and its wait cannot miss the wake-up.
    pub fn stop(&self) {
        let _state = self.shared.state.lock().unwrap();
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.refill.notify_all();
        self.shared.ready.notify_all();
    }

    /// Stop dealers and drain.
    pub fn shutdown(mut self) {
        self.stop();
        for d in self.dealers.drain(..) {
            let _ = d.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::{FaultMode, ReluVariant};
    use crate::protocol::linear::{LinearOp, Matrix};
    use crate::wire::dealer::spawn_mem_dealer_multi;
    use crate::wire::frame::{Channel, Framed, MemChannel, MsgType};

    fn tiny_plan() -> Arc<NetworkPlan> {
        let mut rng = Rng::new(1);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu))
    }

    fn other_plan() -> Arc<NetworkPlan> {
        let mut rng = Rng::new(2);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)),
            Arc::new(Matrix::random(4, 5, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(
            linears,
            ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
        ))
    }

    /// An endpoint backed by a fresh in-memory dealer per connect (the
    /// dealer thread is detached; it exits when its channel drops).
    fn mem_endpoint(label: &str, registry: Arc<ModelRegistry>, conn_seed: u64) -> DealerEndpoint {
        let reg = registry.clone();
        DealerEndpoint::new(
            label,
            Arc::new(move || {
                let (chan, _dealer_thread) = spawn_mem_dealer_multi(reg.clone(), conn_seed, 1);
                RemoteDealer::connect(chan, reg.clone())
            }),
        )
    }

    /// Assert the claim ledger is fully resolved (no records, no
    /// in-flight units) — banks at target imply exactly this.
    fn assert_ledger_quiescent(pool: &MaterialPool) {
        assert_eq!(pool.outstanding_claims(), (0, 0), "claim records outstanding");
        assert_eq!(pool.in_flight_total(), 0, "in-flight units outstanding");
    }

    #[test]
    fn pool_fills_and_leases() {
        let pool = MaterialPool::start(tiny_plan(), 4, 2, 7);
        pool.wait_ready(4);
        assert!(pool.banked() >= 4);
        let mut rng = Rng::new(2);
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        assert_eq!(lease.deal_us, 0);
        assert!(lease.session.offline_bytes > 0);
        pool.shutdown();
    }

    #[test]
    fn dry_lease_still_serves() {
        // Zero-target pool: every lease is dry but must still work.
        let pool = MaterialPool::start(tiny_plan(), 0, 1, 8);
        let mut rng = Rng::new(3);
        let lease = pool.lease(&mut rng);
        assert!(lease.was_dry);
        assert!(lease.deal_us > 0, "inline deal latency must be measured");
        assert_eq!(pool.dry_leases(), 1);
        pool.shutdown();
    }

    #[test]
    fn assembled_sessions_match_whole_session_deal() {
        // The sharding acceptance property, inline edition: a session
        // assembled from per-layer bank entries is bit-identical to a
        // whole-session deal from the same session RNG — identical
        // inference transcripts, not merely correct ones.
        use crate::protocol::server::run_inference;
        let plan = tiny_plan();
        let seed = 0x5EED;
        let pool = MaterialPool::start(plan.clone(), 3, 2, seed);
        pool.wait_ready(3);
        let mut rng = Rng::new(9);
        let input: Vec<crate::field::Fp> =
            (0..6).map(|i| crate::field::Fp::from_i64(900 + i)).collect();
        for seq in 0..3u64 {
            let lease = pool.lease(&mut rng);
            assert!(!lease.was_dry);
            let (client, server, offline_bytes) =
                offline_network_mt(&plan, &mut session_rng(seed, seq), 1);
            assert_eq!(lease.session.offline_bytes, offline_bytes, "seq {seq}");
            let (bank_logits, _) =
                run_inference(&lease.session.client, &lease.session.server, &input);
            let (inline_logits, _) = run_inference(&client, &server, &input);
            assert_eq!(bank_logits, inline_logits, "seq {seq}");
        }
        pool.shutdown();
    }

    #[test]
    fn multi_model_shards_fill_and_lease_from_their_own_namespaces() {
        // Two models in one pool, inline refill: each shard's sessions
        // are bit-identical to inline single-model deals from *that*
        // model's base seed, and neither shard's accounting disturbs the
        // other's.
        use crate::protocol::server::run_inference;
        let (pa, pb) = (tiny_plan(), other_plan());
        let mut reg = ModelRegistry::new();
        let fa = reg.register(pa.clone(), 0xAA, 1.0).unwrap();
        let fb = reg.register(pb.clone(), 0xBB, 3.0).unwrap();
        let registry = Arc::new(reg);
        let pool = MaterialPool::start_multi(
            registry,
            3,
            2,
            RefillSource::Inline,
            None,
            1,
        );
        pool.wait_ready(3);
        assert!(pool.banked_model(fa) >= 3);
        assert!(pool.banked_model(fb) >= 3);
        let mut rng = Rng::new(4);
        let input: Vec<crate::field::Fp> =
            (0..6).map(|i| crate::field::Fp::from_i64(700 + i)).collect();
        for (fp, plan, seed) in [(fa, &pa, 0xAAu64), (fb, &pb, 0xBB)] {
            for seq in 0..2u64 {
                let lease = pool.lease_model(fp, &mut rng);
                assert!(!lease.was_dry, "model {fp:#x} seq {seq}");
                let (client, server, offline_bytes) =
                    offline_network_mt(plan, &mut session_rng(seed, seq), 1);
                assert_eq!(lease.session.offline_bytes, offline_bytes);
                let (bank_logits, _) =
                    run_inference(&lease.session.client, &lease.session.server, &input);
                let (inline_logits, _) = run_inference(&client, &server, &input);
                assert_eq!(bank_logits, inline_logits, "model {fp:#x} seq {seq}");
            }
        }
        assert_eq!(pool.fingerprint_drops(), 0);
        pool.shutdown();
    }

    #[test]
    fn spine_binding_check_catches_mixed_seed_material() {
        // Same-seed pieces bind; pieces from a dealer restarted with a
        // different base seed must be detected before assembly.
        let plan = tiny_plan();
        let spine_a = deal_spine(&plan, &mut session_rng(1, 0));
        let layers_a: Vec<ReluEntry> = (0..plan.n_relu_layers())
            .map(|li| deal_relu_layer_mt(&plan, &mut session_rng(1, 0), li, 1))
            .collect();
        assert!(spine_binds_layers(&plan, &spine_a, &layers_a));
        let layers_b: Vec<ReluEntry> = (0..plan.n_relu_layers())
            .map(|li| deal_relu_layer_mt(&plan, &mut session_rng(2, 0), li, 1))
            .collect();
        assert!(!spine_binds_layers(&plan, &spine_a, &layers_b));
    }

    #[test]
    fn banks_never_overshoot_target() {
        // Claim accounting bounds every bank at exactly `target` even
        // with many racing dealers (the old pool could overshoot to
        // target + n_dealers − 1).
        let pool = MaterialPool::start(tiny_plan(), 3, 4, 11);
        let mut rng = Rng::new(4);
        for _ in 0..3 {
            pool.wait_ready(3);
            assert_eq!(pool.banked(), 3);
            for (b, depth) in pool.bank_depths().into_iter().enumerate() {
                assert!(depth <= 3, "bank {b} overshot: {depth}");
            }
            let _ = pool.lease(&mut rng);
        }
        pool.shutdown();
    }

    #[test]
    fn wait_ready_returns_on_stop_with_dead_dealer() {
        // A remote source that never connects must not hang warmup: once
        // stop() is called, wait_ready returns instead of waiting on the
        // ready condvar forever.
        let connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync> =
            Arc::new(|| Err(crate::util::error::Error::msg("dealer unreachable")));
        let pool = MaterialPool::start_with_source(
            tiny_plan(),
            2,
            1,
            5,
            RefillSource::remote_single(connect, 2),
            None,
            1,
        );
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| pool.wait_ready(1));
            std::thread::sleep(Duration::from_millis(100));
            pool.stop();
            waiter.join().expect("wait_ready returned after stop");
        });
        assert_eq!(pool.banked(), 0);
        pool.shutdown();
    }

    #[test]
    fn remote_refill_source_fills_bank() {
        // The deployment shape: material produced by a dealer "process"
        // (in-memory channel here), streamed in layer-granularly over
        // the wire codec, and banked per layer — with latency/bytes and
        // bank depths recorded, per model and per link.
        let plan = tiny_plan();
        let metrics = Arc::new(Metrics::default());
        let registry = ModelRegistry::single(plan.clone(), 77);
        let reg_c = registry.clone();
        let connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync> = Arc::new(move || {
            let (chan, _dealer_thread) = spawn_mem_dealer_multi(reg_c.clone(), 77, 1);
            RemoteDealer::connect(chan, reg_c.clone())
        });
        let pool = MaterialPool::start_multi(
            registry,
            3,
            1,
            RefillSource::remote_single(connect, 2),
            Some(metrics.clone()),
            1,
        );
        pool.wait_ready(3);
        let mut rng = Rng::new(2);
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        assert!(lease.session.offline_bytes > 0);
        assert!(pool.produced() >= 3);
        assert_eq!(pool.fingerprint_drops(), 0);
        let snap = metrics.snapshot();
        assert!(snap.remote_refills >= 1, "refill rounds recorded");
        assert!(snap.remote_sessions >= 3, "sessions' worth (spines) recorded");
        assert!(snap.layer_entries >= 6, "per-layer units recorded");
        assert!(snap.bytes_offline_wire > 0, "wire bytes recorded");
        assert!(snap.remote_refill_mean_us > 0.0, "fetch latency recorded");
        assert_eq!(snap.bank_depths.len(), 2, "spine bank + one relu bank");
        assert_eq!(snap.links.len(), 1, "one fleet link row");
        assert!(snap.links[0].fetches >= 1, "link fetches recorded");
        assert!(snap.links[0].units >= 6, "link units recorded");
        pool.shutdown();
    }

    #[test]
    fn inline_deals_record_throughput() {
        // tiny_plan has one ReLU layer of 4 → 4 ReLUs per session.
        let metrics = Arc::new(Metrics::default());
        let pool = MaterialPool::start_with_source(
            tiny_plan(),
            3,
            2,
            11,
            RefillSource::Inline,
            Some(metrics.clone()),
            2,
        );
        pool.wait_ready(3);
        let snap = metrics.snapshot();
        assert!(snap.deal_relus >= 12, "relus recorded: {}", snap.deal_relus);
        assert!(snap.deal_relus_per_s > 0.0, "throughput recorded");
        pool.shutdown();
    }

    #[test]
    fn refill_after_lease() {
        let pool = MaterialPool::start(tiny_plan(), 2, 1, 9);
        pool.wait_ready(2);
        let mut rng = Rng::new(4);
        let _ = pool.lease(&mut rng);
        // Dealer should replenish toward the target.
        pool.wait_ready(2);
        assert!(pool.banked() >= 1);
        assert!(pool.produced() >= 3);
        pool.shutdown();
    }

    #[test]
    fn fleet_partitions_across_links_and_fills() {
        // Three links, one seq space: the fleet partitions claims across
        // all links, and the assembled sessions are bit-identical to
        // inline deals from the model's base seed — the producer of each
        // piece is unobservable.
        use crate::protocol::server::run_inference;
        let plan = tiny_plan();
        let seed = 0x0F1EE7;
        let registry = ModelRegistry::single(plan.clone(), seed);
        let endpoints = vec![
            mem_endpoint("mem0", registry.clone(), 10),
            mem_endpoint("mem1", registry.clone(), 11),
            mem_endpoint("mem2", registry.clone(), 12),
        ];
        let pool = MaterialPool::start_multi(
            registry,
            4,
            3,
            RefillSource::remote(endpoints, 2),
            None,
            1,
        );
        pool.wait_ready(4);
        assert_eq!(pool.fingerprint_drops(), 0);
        assert_ledger_quiescent(&pool);
        let labels: Vec<String> = pool.link_states().iter().map(|(l, _)| l.clone()).collect();
        assert_eq!(labels, vec!["mem0", "mem1", "mem2"]);
        let mut rng = Rng::new(5);
        let input: Vec<crate::field::Fp> =
            (0..6).map(|i| crate::field::Fp::from_i64(300 + i)).collect();
        for seq in 0..4u64 {
            let lease = pool.lease(&mut rng);
            assert!(!lease.was_dry, "seq {seq}");
            let (client, server, offline_bytes) =
                offline_network_mt(&plan, &mut session_rng(seed, seq), 1);
            assert_eq!(lease.session.offline_bytes, offline_bytes, "seq {seq}");
            let (fleet_logits, _) =
                run_inference(&lease.session.client, &lease.session.server, &input);
            let (inline_logits, _) = run_inference(&client, &server, &input);
            assert_eq!(fleet_logits, inline_logits, "seq {seq}");
        }
        pool.shutdown();
    }

    /// A channel that delays every read — makes one link's fetches
    /// reliably stale past `steal_after` so the steal path is exercised
    /// deterministically.
    struct SlowChannel {
        inner: Box<dyn Channel>,
        delay: Duration,
    }

    impl Channel for SlowChannel {
        fn send_bytes(&mut self, buf: &[u8]) -> Result<()> {
            self.inner.send_bytes(buf)
        }

        fn recv_exact(&mut self, buf: &mut [u8]) -> Result<()> {
            std::thread::sleep(self.delay);
            self.inner.recv_exact(buf)
        }
    }

    #[test]
    fn stale_claim_is_stolen_and_late_units_dropped() {
        // One slow link, one fast link. The fast link steals the slow
        // link's stale claims; when the slow fetch completes anyway its
        // ticket is gone and the delivery is dropped, never staged — no
        // double-banked seq, no overshoot, and the banks are
        // bit-identical to what a healthy fleet would have staged.
        let plan = tiny_plan();
        let seed = 0x51;
        let registry = ModelRegistry::single(plan.clone(), seed);
        let slow = {
            let reg = registry.clone();
            DealerEndpoint::new(
                "slow",
                Arc::new(move || {
                    let (chan, _t) = spawn_mem_dealer_multi(reg.clone(), 1, 1);
                    let slowed = SlowChannel { inner: chan, delay: Duration::from_millis(60) };
                    RemoteDealer::connect(Box::new(slowed), reg.clone())
                }),
            )
        };
        let fast = mem_endpoint("fast", registry.clone(), 2);
        let pool = MaterialPool::start_multi_tuned(
            registry,
            6,
            2,
            RefillSource::remote(vec![slow, fast], 2),
            None,
            1,
            PoolTuning {
                steal_after: Duration::from_millis(40),
                demand_half_life: Duration::from_secs(10),
            },
        );
        pool.wait_ready(6);
        // The slow link's in-flight fetch resolves (late-dropped)
        // shortly after the steal; poll rather than assume scheduling.
        let deadline = Instant::now() + Duration::from_secs(10);
        while (pool.steals() < 1 || pool.late_drop_units() < 1) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(pool.steals() >= 1, "fast link stole from the slow link");
        assert!(pool.late_drop_units() >= 1, "slow link's late delivery dropped");
        assert_eq!(pool.fingerprint_drops(), 0);
        for (b, depth) in pool.bank_depths().into_iter().enumerate() {
            assert!(depth <= 6, "bank {b} overshot after steals: {depth}");
        }
        // Bit-identity survives stealing: whichever link produced each
        // piece, the session equals the inline deal.
        let mut rng = Rng::new(6);
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        let (_, _, offline_bytes) = offline_network_mt(&plan, &mut session_rng(seed, 0), 1);
        assert_eq!(lease.session.offline_bytes, offline_bytes);
        pool.shutdown();
    }

    #[test]
    fn failed_link_hands_off_claims_and_pool_still_fills() {
        // Link-scoped poisoning regression: one endpoint serves the
        // handshake then drops every fetch. Its claims are handed off
        // (abandoned → re-issued), the healthy link fills the banks, and
        // the pool serves bit-identical sessions — a broken link costs a
        // handoff, never the pool.
        use crate::protocol::server::run_inference;
        use crate::wire::codec;
        let plan = tiny_plan();
        let seed = 0xBAD;
        let registry = ModelRegistry::single(plan.clone(), seed);
        let bad = {
            let reg = registry.clone();
            DealerEndpoint::new(
                "bad",
                Arc::new(move || {
                    let (coord_end, dealer_end) = MemChannel::pair();
                    let manifests = reg.manifests();
                    std::thread::spawn(move || {
                        let mut framed = Framed::new(Box::new(dealer_end));
                        if framed.recv().is_ok() {
                            let set = codec::encode_manifest_set(&manifests).unwrap();
                            let _ = framed.send(MsgType::Hello, &set);
                        }
                        // Dropped here: every subsequent fetch on this
                        // link fails at the transport.
                    });
                    RemoteDealer::connect(Box::new(coord_end), reg.clone())
                }),
            )
        };
        let good = {
            let reg = registry.clone();
            DealerEndpoint::new(
                "good",
                Arc::new(move || {
                    // Let the bad link claim (and fail) first so the
                    // handoff path is exercised deterministically.
                    std::thread::sleep(Duration::from_millis(200));
                    let (chan, _t) = spawn_mem_dealer_multi(reg.clone(), 3, 1);
                    RemoteDealer::connect(chan, reg.clone())
                }),
            )
        };
        let pool = MaterialPool::start_multi_tuned(
            registry,
            4,
            2,
            RefillSource::remote(vec![bad, good], 2),
            None,
            1,
            PoolTuning {
                steal_after: Duration::from_secs(5),
                demand_half_life: Duration::from_secs(10),
            },
        );
        pool.wait_ready(4);
        assert!(pool.reissued_seqs() >= 1, "failed fetches handed their claims off");
        assert_eq!(pool.fingerprint_drops(), 0);
        let mut rng = Rng::new(7);
        let input: Vec<crate::field::Fp> =
            (0..6).map(|i| crate::field::Fp::from_i64(40 + i)).collect();
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        let (client, server, _) = offline_network_mt(&plan, &mut session_rng(seed, 0), 1);
        let (fleet_logits, _) =
            run_inference(&lease.session.client, &lease.session.server, &input);
        let (inline_logits, _) = run_inference(&client, &server, &input);
        assert_eq!(fleet_logits, inline_logits);
        pool.shutdown();
    }

    #[test]
    fn ewma_weights_shift_with_traffic() {
        // Zero-target pool (no dealing noise): before traffic the
        // effective weights are the registry's static priors; once one
        // model takes the traffic its weight dominates; after a traffic
        // flip the ordering reverses within a few half-lives.
        let (pa, pb) = (tiny_plan(), other_plan());
        let mut reg = ModelRegistry::new();
        let fa = reg.register(pa, 0xA1, 2.0).unwrap();
        let fb = reg.register(pb, 0xB2, 1.0).unwrap();
        let pool = MaterialPool::start_multi_tuned(
            Arc::new(reg),
            0,
            1,
            RefillSource::Inline,
            None,
            1,
            PoolTuning {
                steal_after: Duration::from_millis(1000),
                demand_half_life: Duration::from_millis(50),
            },
        );
        let cold = pool.effective_weights();
        assert_eq!(cold[0].0, fa);
        assert_eq!(cold[1].0, fb);
        assert!((cold[0].1 - 2.0).abs() < 1e-12, "cold weights are the demand priors");
        assert!((cold[1].1 - 1.0).abs() < 1e-12);
        let mut rng = Rng::new(8);
        for _ in 0..20 {
            let _ = pool.lease_model(fa, &mut rng);
        }
        let hot_a = pool.effective_weights();
        assert!(
            hot_a[0].1 > 5.0 * hot_a[1].1,
            "A takes the traffic, A dominates: {hot_a:?}"
        );
        assert!(hot_a[1].1 >= WEIGHT_FLOOR, "cold model keeps the floor");
        // Flip the traffic; A's score decays over a few half-lives
        // while B's accumulates.
        std::thread::sleep(Duration::from_millis(150));
        for _ in 0..20 {
            let _ = pool.lease_model(fb, &mut rng);
        }
        let hot_b = pool.effective_weights();
        assert!(
            hot_b[1].1 > hot_b[0].1,
            "traffic flip re-aims the weights: {hot_b:?}"
        );
        pool.shutdown();
    }

    #[test]
    fn claim_aiming_follows_weights() {
        // claim_weighted_emptiest honors the priors cold and the EWMA
        // once traffic exists — pinned directly on shard state, no
        // threads.
        let mk = |plan: Arc<NetworkPlan>, fp: u64, demand: f64| Shard {
            fingerprint: fp,
            plan: plan.clone(),
            base_seed: fp,
            demand,
            lease_rate: LeaseRate::new(Duration::from_secs(10)),
            bank: Bank::new(plan.n_relu_layers()),
            high_water: 0,
        };
        let now = Instant::now();
        // Cold: static priors decide (A's 5.0 beats B's 1.0).
        let mut shards = vec![mk(tiny_plan(), 1, 5.0), mk(other_plan(), 2, 1.0)];
        let (si, b, seqs) = claim_weighted_emptiest(&mut shards, 2, 1, now).unwrap();
        assert_eq!(si, 0, "cold claims aim at the higher static prior");
        shards[si].bank.abandon(b, &seqs);
        // Hot: B's lease traffic overrides A's prior.
        for _ in 0..4 {
            shards[1].lease_rate.bump(now);
        }
        let (si, _, _) = claim_weighted_emptiest(&mut shards, 2, 1, now).unwrap();
        assert_eq!(si, 1, "traffic re-aims claims at the busy model");
    }
}

//! The offline-material bank, sharded by **model and layer**.
//!
//! Real PI fleets serve several architectures at once (Circa's per-ReLU
//! savings compose with CryptoNAS/DeepReDuce-style network-level ReLU
//! reduction), and each network concentrates its ReLUs in a few wide
//! layers. The bank therefore holds one **shard per registered model**
//! (keyed by the plan's manifest fingerprint via [`ModelRegistry`]),
//! and inside each shard *per-layer* banks: one bank of linear-precompute
//! spines ([`LinearSpine`] — masks, HE precomputes, blinds; cheap) plus
//! one bank per ReLU layer (garbled tables, label arenas, triples; the
//! expensive part), each keyed by a session **sequence number** in that
//! model's own seq namespace (its registry base seed). Dealers refill
//! the emptiest `(model, layer)` bank first — deficits weighted by each
//! model's demand rate (the registry entry's
//! [`demand`](crate::coordinator::registry::ModelEntry::demand) weight)
//! so a model taking 3× the traffic gets its banks refilled 3× as
//! eagerly — and [`MaterialPool::lease_model`] assembles a
//! [`Session`] from the front entry of every bank of that model's shard.
//!
//! Seq-addressing is what makes the shards composable: entry `(model,
//! bank, seq)` is a pure function of `(model base seed, seq, layer)`
//! under the per-layer forked session schedule
//! ([`crate::protocol::server::session_rng`]), so independently dealt
//! entries with equal seqs assemble into exactly the session a whole
//! inline deal from that session RNG would produce — bit-identical,
//! whichever dealer thread or connection produced each piece. Leases pop
//! every bank's front at once, so a shard's fronts stay seq-aligned
//! structurally, and per-model base seeds keep two shards' seq spaces
//! from ever colliding.
//!
//! Refills come from a [`RefillSource`]: the inline deal (garble
//! in-process, from the shard's own base seed) or a remote dealer
//! process reached over [`crate::wire`]'s model-addressed layer-granular
//! streaming round — the paper's deployment shape, with the largest
//! frame bounded by the largest single layer batch. Claim accounting is
//! exact **per shard**: a bank's staged + in-flight entries never exceed
//! `target`, so racing dealer threads cannot overshoot any bank and a
//! hot model cannot starve accounting of a cold one (cross-model
//! overshoot is structurally impossible — claims are committed against
//! one `(model, bank)` pair). Remote units are fingerprint-checked at
//! staging: a `LayerBatch`/`Spine` tagged with another model's
//! fingerprint is dropped and counted
//! ([`MaterialPool::fingerprint_drops`]), never banked into the wrong
//! shard. Failed claims are abandoned back into a retry list, and
//! [`MaterialPool::wait_ready`] is stop-aware, so a dealer that never
//! connects cannot hang warmup or shutdown forever.

use super::metrics::Metrics;
use super::registry::ModelRegistry;
use crate::protocol::client::ClientNet;
use crate::protocol::offline::{ClientReluMaterial, ServerReluMaterial};
use crate::protocol::server::{
    assemble_session, deal_relu_layer_mt, deal_spine, offline_network_mt, session_rng,
    LinearSpine, NetworkPlan, ServerNet,
};
use crate::util::error::Result;
use crate::util::{Rng, Timer};
use crate::wire::dealer::RemoteDealer;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One ready-to-serve inference session.
pub struct Session {
    pub client: ClientNet,
    pub server: ServerNet,
    pub offline_bytes: u64,
}

impl Session {
    /// ReLUs of offline material in this session (the deal-throughput
    /// denominator).
    pub fn n_relus(&self) -> usize {
        self.server.n_relus()
    }
}

/// Outcome of [`MaterialPool::lease_model`]: the session plus where it
/// came from. A dry lease carries the inline-deal latency so the caller
/// can surface it as tail latency (the serving metrics record it).
pub struct Lease {
    pub session: Session,
    pub was_dry: bool,
    /// Microseconds spent dealing inline (0 for banked sessions).
    pub deal_us: u64,
}

type ReluEntry = (ClientReluMaterial, ServerReluMaterial);

/// Count keys `head, head+1, …` present in `m` (the bank's ready run).
fn contiguous_from<V>(m: &BTreeMap<u64, V>, head: u64) -> usize {
    let mut n = 0u64;
    for (&k, _) in m.range(head..) {
        if k != head + n {
            break;
        }
        n += 1;
    }
    n as usize
}

/// One model's layer-sharded bank. Bank index 0 holds linear spines;
/// bank `1 + li` holds ReLU layer `li`. Entries are staged in
/// `BTreeMap`s keyed by seq because completions can land out of order
/// (racing dealers, retried claims); contiguity from `head` is what
/// counts as ready.
struct Bank {
    /// Seq of the next session [`MaterialPool::lease_model`] will
    /// assemble.
    head: u64,
    spines: BTreeMap<u64, LinearSpine>,
    relus: Vec<BTreeMap<u64, ReluEntry>>,
    /// Next fresh seq each bank hands out to a dealer claim.
    next_claim: Vec<u64>,
    /// Claims handed out but not yet completed or abandoned.
    in_flight: Vec<usize>,
    /// Abandoned claims, re-dealt before fresh seqs are claimed.
    retries: Vec<Vec<u64>>,
}

impl Bank {
    fn new(n_relu: usize) -> Self {
        Bank {
            head: 0,
            spines: BTreeMap::new(),
            relus: (0..n_relu).map(|_| BTreeMap::new()).collect(),
            next_claim: vec![0; 1 + n_relu],
            in_flight: vec![0; 1 + n_relu],
            retries: (0..n_relu + 1).map(|_| Vec::new()).collect(),
        }
    }

    fn n_banks(&self) -> usize {
        1 + self.relus.len()
    }

    fn staged(&self, b: usize) -> usize {
        if b == 0 {
            self.spines.len()
        } else {
            self.relus[b - 1].len()
        }
    }

    /// Entries committed against `target`: staged plus in-flight claims
    /// (abandoned retries are uncommitted — they need re-dealing).
    fn supply(&self, b: usize) -> usize {
        self.staged(b) + self.in_flight[b]
    }

    /// Claim up to `max` seqs from bank `b`, retries first (caller has
    /// already picked `b` by weighted deficit — claim accounting is what
    /// makes overshoot impossible).
    fn claim(&mut self, b: usize, n: usize) -> Vec<u64> {
        (0..n)
            .map(|_| {
                self.in_flight[b] += 1;
                self.retries[b].pop().unwrap_or_else(|| {
                    let s = self.next_claim[b];
                    self.next_claim[b] += 1;
                    s
                })
            })
            .collect()
    }

    fn abandon(&mut self, b: usize, seqs: &[u64]) {
        self.in_flight[b] -= seqs.len();
        self.retries[b].extend_from_slice(seqs);
    }

    fn complete_spine(&mut self, seq: u64, spine: LinearSpine) {
        self.in_flight[0] -= 1;
        self.spines.insert(seq, spine);
    }

    fn complete_relu(&mut self, li: usize, seq: u64, entry: ReluEntry) {
        self.in_flight[1 + li] -= 1;
        self.relus[li].insert(seq, entry);
    }

    /// Sessions assemblable right now: the shortest contiguous run from
    /// `head` across all banks.
    fn ready_run(&self) -> usize {
        let mut run = contiguous_from(&self.spines, self.head);
        for m in &self.relus {
            run = run.min(contiguous_from(m, self.head));
        }
        run
    }

    /// Pop the front entry of every bank (requires `ready_run() >= 1`).
    /// Popping all banks at once is what keeps the fronts seq-aligned.
    fn pop_head(&mut self) -> (LinearSpine, Vec<ReluEntry>) {
        let head = self.head;
        let spine = self.spines.remove(&head).expect("ready head spine");
        let relus: Vec<ReluEntry> = self
            .relus
            .iter_mut()
            .map(|m| m.remove(&head).expect("ready head layer"))
            .collect();
        self.head += 1;
        (spine, relus)
    }

    fn depths(&self) -> Vec<usize> {
        (0..self.n_banks()).map(|b| self.staged(b)).collect()
    }
}

/// One registered model's shard of the pool.
struct Shard {
    fingerprint: u64,
    plan: Arc<NetworkPlan>,
    /// This model's seq-addressed dealing namespace (inline refills and
    /// the shape the remote dealer must reproduce from *its* registry).
    base_seed: u64,
    /// Refill-priority weight (scales this shard's bank deficits).
    demand: f64,
    bank: Bank,
    /// High-water mark of `head + ready_run()` — sessions ever made
    /// assemblable from this shard.
    high_water: u64,
}

struct Shared {
    shards: Mutex<Vec<Shard>>,
    ready: Condvar,
    refill: Condvar,
    stop: AtomicBool,
    dry_leases: AtomicU64,
    /// Remote units dropped because their fingerprint tag named another
    /// model (never banked into the wrong shard).
    fp_drops: AtomicU64,
}

/// Pick the `(shard, bank)` pair with the largest demand-weighted
/// deficit and claim up to `max` seqs from it. `None` when every bank of
/// every shard is at target.
fn claim_weighted_emptiest(
    shards: &mut [Shard],
    target: usize,
    max: usize,
) -> Option<(usize, usize, Vec<u64>)> {
    let mut best: Option<(usize, usize, usize)> = None;
    let mut best_w = 0.0f64;
    for (si, sh) in shards.iter().enumerate() {
        for b in 0..sh.bank.n_banks() {
            let deficit = target.saturating_sub(sh.bank.supply(b));
            if deficit == 0 {
                continue;
            }
            let w = deficit as f64 * sh.demand;
            if w > best_w {
                best_w = w;
                best = Some((si, b, deficit));
            }
        }
    }
    let (si, b, deficit) = best?;
    let n = deficit.min(max.max(1));
    let seqs = shards[si].bank.claim(b, n);
    Some((si, b, seqs))
}

/// Update a shard's produced high-water mark and its metrics depth gauge
/// after completions land (caller holds the shards lock).
fn publish_progress(shards: &mut [Shard], si: usize, metrics: &Option<Arc<Metrics>>) {
    let sh = &mut shards[si];
    let high_water = sh.bank.head + sh.bank.ready_run() as u64;
    sh.high_water = sh.high_water.max(high_water);
    if let Some(m) = metrics {
        m.set_bank_depths(
            sh.fingerprint,
            sh.bank.depths().iter().map(|&d| d as u64).collect(),
        );
    }
}

/// Cross-check that every ReLU layer's `r_out` chain binds to the
/// spine's mask chain (`truncate(r_out[li]) == spine.slots[li+1].r`).
/// Seq-aligned pops make mixed-seq assembly structurally impossible
/// *within* one shard, but a remote dealer restarted with a different
/// base seed mid-stream would fill later claims from a different RNG
/// universe — this O(#ReLU) check catches that before a silently-wrong
/// session is served.
fn spine_binds_layers(plan: &NetworkPlan, spine: &LinearSpine, relus: &[ReluEntry]) -> bool {
    for (li, (cm, _)) in relus.iter().enumerate() {
        let rescale = plan.rescale_of(li);
        let want = &spine.slots[li + 1].r;
        if cm.r_out.len() != want.len() {
            return false;
        }
        let bound = cm
            .r_out
            .iter()
            .zip(want.iter())
            .all(|(&y, &m)| crate::nn::layers::truncate_share_local(y, rescale, true) == m);
        if !bound {
            return false;
        }
    }
    true
}

/// Where dealer threads get their material.
pub enum RefillSource {
    /// Deal layer entries inline in local dealer threads (the default).
    Inline,
    /// Stream per-layer material from a remote dealer process over the
    /// model-addressed layer-granular wire round. `connect` is called
    /// (and re-called after transport errors) to establish a
    /// [`RemoteDealer`]; `batch` caps entries per round trip. All
    /// connections must reach dealers sharing one registry (per-model
    /// base seeds) — seq-addressing makes their answers mutually
    /// consistent.
    Remote {
        connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync>,
        batch: usize,
    },
}

enum Fetched {
    Spines(Vec<(u64, u64, LinearSpine)>),
    Layers(Vec<(u64, u64, ClientReluMaterial, ServerReluMaterial)>),
}

/// Material bank with background dealer threads, sharded per registered
/// model.
pub struct MaterialPool {
    registry: Arc<ModelRegistry>,
    shared: Arc<Shared>,
    target: usize,
    deal_threads: usize,
    metrics: Option<Arc<Metrics>>,
    dealers: Vec<JoinHandle<()>>,
}

impl MaterialPool {
    /// Spawn a single-model pool refilling every bank toward `target`
    /// with `n_dealers` inline dealer threads. The model's seq namespace
    /// is exactly `seed` (dealt bytes identical to the pre-registry
    /// single-model pool for the same `(seed, plan)`).
    pub fn start(plan: Arc<NetworkPlan>, target: usize, n_dealers: usize, seed: u64) -> Self {
        Self::start_with_source(plan, target, n_dealers, seed, RefillSource::Inline, None, 1)
    }

    /// Single-model pool with an explicit [`RefillSource`] (a registry of
    /// one plan under base seed `seed`). See [`Self::start_multi`].
    pub fn start_with_source(
        plan: Arc<NetworkPlan>,
        target: usize,
        n_dealers: usize,
        seed: u64,
        source: RefillSource,
        metrics: Option<Arc<Metrics>>,
        deal_threads: usize,
    ) -> Self {
        Self::start_multi(
            ModelRegistry::single(plan, seed),
            target,
            n_dealers,
            source,
            metrics,
            deal_threads,
        )
    }

    /// Spawn a pool with one shard per model in `registry`. When
    /// `metrics` is given, remote refills record their latency and
    /// bytes-on-wire, inline deals record their ReLU throughput, and the
    /// per-bank depth gauges are published — all labeled per model.
    /// `deal_threads` splits each inline (and dry-lease) deal's garble
    /// and triple columns across threads — the column-wise RNG schedule
    /// keeps the material bit-identical for every value.
    pub fn start_multi(
        registry: Arc<ModelRegistry>,
        target: usize,
        n_dealers: usize,
        source: RefillSource,
        metrics: Option<Arc<Metrics>>,
        deal_threads: usize,
    ) -> Self {
        assert!(!registry.is_empty(), "pool needs at least one registered model");
        let deal_threads = deal_threads.max(1);
        let shards: Vec<Shard> = registry
            .entries()
            .iter()
            .map(|e| Shard {
                fingerprint: e.fingerprint(),
                plan: e.plan.clone(),
                base_seed: e.base_seed,
                demand: e.demand,
                bank: Bank::new(e.plan.n_relu_layers()),
                high_water: 0,
            })
            .collect();
        let shared = Arc::new(Shared {
            shards: Mutex::new(shards),
            ready: Condvar::new(),
            refill: Condvar::new(),
            stop: AtomicBool::new(false),
            dry_leases: AtomicU64::new(0),
            fp_drops: AtomicU64::new(0),
        });
        let mut dealers = Vec::new();
        for d in 0..n_dealers.max(1) {
            let shared = shared.clone();
            let metrics = metrics.clone();
            let remote = match &source {
                RefillSource::Inline => None,
                RefillSource::Remote { connect, batch } => {
                    Some((connect.clone(), (*batch).max(1)))
                }
            };
            dealers.push(std::thread::spawn(move || {
                let mut conn: Option<RemoteDealer> = None;
                // Connect + fetch failures share one counter, reset only
                // on a successful fetch — a dealer that handshakes but
                // fails every fetch still gets surfaced.
                let mut failures = 0u64;
                // Rounds that delivered fingerprint-mismatched units
                // (throttles the mistagging-dealer log like `failures`
                // throttles transport errors — a lying dealer retries
                // forever and must not flood stderr).
                let mut drop_rounds = 0u64;
                let claim_max = remote.as_ref().map_or(1, |(_, batch)| *batch);
                loop {
                    // Claim work from the emptiest (model, bank) pair —
                    // deficits demand-weighted — waiting while every bank
                    // of every shard is at target.
                    let (si, bank_idx, seqs, fp, plan, base_seed) = {
                        let mut shards = shared.shards.lock().unwrap();
                        loop {
                            if shared.stop.load(Ordering::Relaxed) {
                                return;
                            }
                            match claim_weighted_emptiest(&mut shards, target, claim_max) {
                                Some((si, b, seqs)) => {
                                    let sh = &shards[si];
                                    break (
                                        si,
                                        b,
                                        seqs,
                                        sh.fingerprint,
                                        sh.plan.clone(),
                                        sh.base_seed,
                                    );
                                }
                                None => shards = shared.refill.wait(shards).unwrap(),
                            }
                        }
                    };
                    match &remote {
                        None => {
                            // Inline: deal the claimed entry outside the
                            // lock (garbling is slow); the deal itself
                            // fans out over deal_threads.
                            let seq = seqs[0];
                            if bank_idx == 0 {
                                let spine = deal_spine(&plan, &mut session_rng(base_seed, seq));
                                let mut shards = shared.shards.lock().unwrap();
                                shards[si].bank.complete_spine(seq, spine);
                                publish_progress(&mut shards, si, &metrics);
                            } else {
                                let li = bank_idx - 1;
                                let t = Timer::new();
                                let (cm, sm) = deal_relu_layer_mt(
                                    &plan,
                                    &mut session_rng(base_seed, seq),
                                    li,
                                    deal_threads,
                                );
                                if let Some(m) = &metrics {
                                    m.record_deal(fp, cm.n() as u64, t.elapsed_us());
                                }
                                let mut shards = shared.shards.lock().unwrap();
                                shards[si].bank.complete_relu(li, seq, (cm, sm));
                                publish_progress(&mut shards, si, &metrics);
                            }
                            shared.ready.notify_all();
                        }
                        Some((connect, _)) => {
                            if conn.is_none() {
                                match connect() {
                                    Ok(dealer) => conn = Some(dealer),
                                    Err(e) => {
                                        // Surface the failure (throttled):
                                        // a dead/mismatched dealer would
                                        // otherwise starve the banks
                                        // silently.
                                        failures += 1;
                                        if failures.is_power_of_two() {
                                            eprintln!(
                                                "[pool d{d}] dealer connect failed \
                                                 ({failures}x): {e}"
                                            );
                                        }
                                        let mut shards = shared.shards.lock().unwrap();
                                        shards[si].bank.abandon(bank_idx, &seqs);
                                        drop(shards);
                                        std::thread::sleep(Duration::from_millis(50));
                                        continue;
                                    }
                                }
                            }
                            let dealer = conn.as_mut().unwrap();
                            let before = dealer.bytes_received();
                            let t = Timer::new();
                            let fetched: Result<Fetched> = if bank_idx == 0 {
                                dealer.fetch_spines(fp, &seqs).map(Fetched::Spines)
                            } else {
                                dealer
                                    .fetch_layers(fp, bank_idx - 1, &seqs)
                                    .map(Fetched::Layers)
                            };
                            let fetch_us = t.elapsed_us();
                            let wire_bytes = dealer.bytes_received() - before;
                            match fetched {
                                Ok(units) => {
                                    failures = 0;
                                    // Stage fingerprint-matching units;
                                    // drop + count + re-claim the rest —
                                    // a unit tagged for model B can never
                                    // land in model A's shard.
                                    let mut dropped: Vec<u64> = Vec::new();
                                    let mut staged = 0u64;
                                    let mut staged_spines = 0u64;
                                    let mut shards = shared.shards.lock().unwrap();
                                    match units {
                                        Fetched::Spines(v) => {
                                            for (ufp, seq, spine) in v {
                                                if ufp == fp {
                                                    staged += 1;
                                                    staged_spines += 1;
                                                    shards[si]
                                                        .bank
                                                        .complete_spine(seq, spine);
                                                } else {
                                                    dropped.push(seq);
                                                }
                                            }
                                        }
                                        Fetched::Layers(v) => {
                                            for (ufp, seq, cm, sm) in v {
                                                if ufp == fp {
                                                    staged += 1;
                                                    shards[si].bank.complete_relu(
                                                        bank_idx - 1,
                                                        seq,
                                                        (cm, sm),
                                                    );
                                                } else {
                                                    dropped.push(seq);
                                                }
                                            }
                                        }
                                    }
                                    if !dropped.is_empty() {
                                        shared
                                            .fp_drops
                                            .fetch_add(dropped.len() as u64, Ordering::Relaxed);
                                        if let Some(m) = &metrics {
                                            m.fp_mismatch_drops.fetch_add(
                                                dropped.len() as u64,
                                                Ordering::Relaxed,
                                            );
                                        }
                                        shards[si].bank.abandon(bank_idx, &dropped);
                                    }
                                    // Only material that actually staged
                                    // counts toward the model's refill
                                    // row — a mistagging dealer must not
                                    // make a starved model look well fed.
                                    // Recorded under the shards lock so
                                    // a wait_ready waiter can never see
                                    // the staging without its counters.
                                    if let Some(m) = &metrics {
                                        m.record_layer_refill(
                                            fp,
                                            fetch_us.max(1),
                                            wire_bytes,
                                            staged,
                                            staged_spines,
                                        );
                                    }
                                    publish_progress(&mut shards, si, &metrics);
                                    drop(shards);
                                    shared.ready.notify_all();
                                    if !dropped.is_empty() {
                                        // A mistagging dealer is a
                                        // failure mode, not a hot path:
                                        // surface it (throttled, outside
                                        // the lock) and slow the re-claim
                                        // so the abandoned seqs don't
                                        // spin.
                                        drop_rounds += 1;
                                        if drop_rounds.is_power_of_two() {
                                            eprintln!(
                                                "[pool d{d}] dropped {} unit(s) tagged for \
                                                 another model (wanted {fp:#018x}; \
                                                 {drop_rounds} rounds affected)",
                                                dropped.len()
                                            );
                                        }
                                        std::thread::sleep(Duration::from_millis(50));
                                    }
                                }
                                Err(e) => {
                                    // Transport hiccup: surface it
                                    // (throttled), put the claims back,
                                    // drop the link, reconnect next
                                    // round.
                                    failures += 1;
                                    if failures.is_power_of_two() {
                                        eprintln!(
                                            "[pool d{d}] layer fetch failed \
                                             ({failures}x): {e}"
                                        );
                                    }
                                    let mut shards = shared.shards.lock().unwrap();
                                    shards[si].bank.abandon(bank_idx, &seqs);
                                    drop(shards);
                                    conn = None;
                                    std::thread::sleep(Duration::from_millis(50));
                                }
                            }
                        }
                    }
                }
            }));
        }
        Self { registry, shared, target, deal_threads, metrics, dealers }
    }

    /// The pool's model registry (shared with the service and the remote
    /// connect closure).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    fn shard_index(&self, model: u64) -> usize {
        self.registry
            .index_of(model)
            .unwrap_or_else(|| panic!("model {model:#018x} not registered with this pool"))
    }

    /// [`Self::lease_model`] for the first registered model (the
    /// single-model convenience).
    pub fn lease(&self, rng: &mut Rng) -> Lease {
        self.lease_model(self.registry.entries()[0].fingerprint(), rng)
    }

    /// Lease a session of model `model`: assemble one from its shard's
    /// front entries, or deal inline when no full session is ready. The
    /// dry path measures the inline deal so callers can record it into
    /// the serving [`super::Metrics`] — pool-dry tail latency is exactly
    /// what a deployment's offline-throughput shortfall looks like.
    /// Panics if `model` is not registered (the service validates at
    /// submission).
    pub fn lease_model(&self, model: u64, rng: &mut Rng) -> Lease {
        let si = self.shard_index(model);
        let popped = {
            let mut shards = self.shared.shards.lock().unwrap();
            if shards[si].bank.ready_run() >= 1 {
                let entry = shards[si].bank.pop_head();
                // Keep the depth gauge honest while leases drain the
                // banks (the produced high-water update inside is a
                // monotone no-op on pops).
                publish_progress(&mut shards, si, &self.metrics);
                Some(entry)
            } else {
                None
            }
        };
        let plan = self.registry.entries()[si].plan.clone();
        if let Some((spine, relus)) = popped {
            self.shared.refill.notify_all();
            if spine_binds_layers(&plan, &spine, &relus) {
                let (client, server, offline_bytes) = assemble_session(&plan, spine, relus);
                return Lease {
                    session: Session { client, server, offline_bytes },
                    was_dry: false,
                    deal_us: 0,
                };
            }
            // Mixed-universe material (e.g. a remote dealer restarted
            // with a different base seed mid-stream): refuse to serve
            // it, surface loudly, and fall through to a dry deal.
            eprintln!(
                "[pool] discarding banked session of model {model:#018x}: layer material \
                 does not bind to its spine (dealer base seed changed mid-stream?)"
            );
        }
        // Dry: prepare inline, and time it.
        self.shared.dry_leases.fetch_add(1, Ordering::Relaxed);
        let t = Timer::new();
        let (client, server, offline_bytes) =
            offline_network_mt(&plan, rng, self.deal_threads);
        Lease {
            session: Session { client, server, offline_bytes },
            was_dry: true,
            deal_us: t.elapsed_us(),
        }
    }

    /// Block until at least `n` full sessions are assemblable for
    /// **every** registered model (warmup). Stop-aware: returns early
    /// once [`Self::stop`]/[`Self::shutdown`] is called, so a dealer
    /// that never connects cannot hang warmup forever.
    pub fn wait_ready(&self, n: usize) {
        let want = n.min(self.target);
        let mut shards = self.shared.shards.lock().unwrap();
        while shards.iter().any(|s| s.bank.ready_run() < want)
            && !self.shared.stop.load(Ordering::Relaxed)
        {
            shards = self.shared.ready.wait(shards).unwrap();
        }
    }

    /// Full sessions assemblable right now for every model (the minimum
    /// across shards; single-model pools read as before).
    pub fn banked(&self) -> usize {
        let shards = self.shared.shards.lock().unwrap();
        shards.iter().map(|s| s.bank.ready_run()).min().unwrap_or(0)
    }

    /// Full sessions assemblable right now for one model.
    pub fn banked_model(&self, model: u64) -> usize {
        let si = self.shard_index(model);
        self.shared.shards.lock().unwrap()[si].bank.ready_run()
    }

    /// Staged entries per bank of the **first registered model** (index
    /// 0 = linear spines, `1 + li` = ReLU layer `li`) — the single-model
    /// convenience; see [`Self::bank_depths_model`].
    pub fn bank_depths(&self) -> Vec<usize> {
        self.bank_depths_model(self.registry.entries()[0].fingerprint())
    }

    /// Staged entries per bank of one model's shard.
    pub fn bank_depths_model(&self, model: u64) -> Vec<usize> {
        let si = self.shard_index(model);
        self.shared.shards.lock().unwrap()[si].bank.depths()
    }

    pub fn dry_leases(&self) -> u64 {
        self.shared.dry_leases.load(Ordering::Relaxed)
    }

    /// Remote units dropped at staging because their fingerprint tag
    /// named another model.
    pub fn fingerprint_drops(&self) -> u64 {
        self.shared.fp_drops.load(Ordering::Relaxed)
    }

    /// Sessions ever made assemblable from the banks, summed across
    /// shards (high-water mark).
    pub fn produced(&self) -> u64 {
        self.shared.shards.lock().unwrap().iter().map(|s| s.high_water).sum()
    }

    /// Sessions ever made assemblable for one model.
    pub fn produced_model(&self, model: u64) -> u64 {
        let si = self.shard_index(model);
        self.shared.shards.lock().unwrap()[si].high_water
    }

    /// Signal dealers and waiters to stop, without joining. The lock is
    /// held across the notify so a waiter between its predicate check
    /// and its wait cannot miss the wake-up.
    pub fn stop(&self) {
        let _shards = self.shared.shards.lock().unwrap();
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.refill.notify_all();
        self.shared.ready.notify_all();
    }

    /// Stop dealers and drain.
    pub fn shutdown(mut self) {
        self.stop();
        for d in self.dealers.drain(..) {
            let _ = d.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::{FaultMode, ReluVariant};
    use crate::protocol::linear::{LinearOp, Matrix};

    fn tiny_plan() -> Arc<NetworkPlan> {
        let mut rng = Rng::new(1);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu))
    }

    fn other_plan() -> Arc<NetworkPlan> {
        let mut rng = Rng::new(2);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)),
            Arc::new(Matrix::random(4, 5, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(
            linears,
            ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
        ))
    }

    #[test]
    fn pool_fills_and_leases() {
        let pool = MaterialPool::start(tiny_plan(), 4, 2, 7);
        pool.wait_ready(4);
        assert!(pool.banked() >= 4);
        let mut rng = Rng::new(2);
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        assert_eq!(lease.deal_us, 0);
        assert!(lease.session.offline_bytes > 0);
        pool.shutdown();
    }

    #[test]
    fn dry_lease_still_serves() {
        // Zero-target pool: every lease is dry but must still work.
        let pool = MaterialPool::start(tiny_plan(), 0, 1, 8);
        let mut rng = Rng::new(3);
        let lease = pool.lease(&mut rng);
        assert!(lease.was_dry);
        assert!(lease.deal_us > 0, "inline deal latency must be measured");
        assert_eq!(pool.dry_leases(), 1);
        pool.shutdown();
    }

    #[test]
    fn assembled_sessions_match_whole_session_deal() {
        // The sharding acceptance property, inline edition: a session
        // assembled from per-layer bank entries is bit-identical to a
        // whole-session deal from the same session RNG — identical
        // inference transcripts, not merely correct ones.
        use crate::protocol::server::run_inference;
        let plan = tiny_plan();
        let seed = 0x5EED;
        let pool = MaterialPool::start(plan.clone(), 3, 2, seed);
        pool.wait_ready(3);
        let mut rng = Rng::new(9);
        let input: Vec<crate::field::Fp> =
            (0..6).map(|i| crate::field::Fp::from_i64(900 + i)).collect();
        for seq in 0..3u64 {
            let lease = pool.lease(&mut rng);
            assert!(!lease.was_dry);
            let (client, server, offline_bytes) =
                offline_network_mt(&plan, &mut session_rng(seed, seq), 1);
            assert_eq!(lease.session.offline_bytes, offline_bytes, "seq {seq}");
            let (bank_logits, _) =
                run_inference(&lease.session.client, &lease.session.server, &input);
            let (inline_logits, _) = run_inference(&client, &server, &input);
            assert_eq!(bank_logits, inline_logits, "seq {seq}");
        }
        pool.shutdown();
    }

    #[test]
    fn multi_model_shards_fill_and_lease_from_their_own_namespaces() {
        // Two models in one pool, inline refill: each shard's sessions
        // are bit-identical to inline single-model deals from *that*
        // model's base seed, and neither shard's accounting disturbs the
        // other's.
        use crate::protocol::server::run_inference;
        let (pa, pb) = (tiny_plan(), other_plan());
        let mut reg = ModelRegistry::new();
        let fa = reg.register(pa.clone(), 0xAA, 1.0).unwrap();
        let fb = reg.register(pb.clone(), 0xBB, 3.0).unwrap();
        let registry = Arc::new(reg);
        let pool = MaterialPool::start_multi(
            registry,
            3,
            2,
            RefillSource::Inline,
            None,
            1,
        );
        pool.wait_ready(3);
        assert!(pool.banked_model(fa) >= 3);
        assert!(pool.banked_model(fb) >= 3);
        let mut rng = Rng::new(4);
        let input: Vec<crate::field::Fp> =
            (0..6).map(|i| crate::field::Fp::from_i64(700 + i)).collect();
        for (fp, plan, seed) in [(fa, &pa, 0xAAu64), (fb, &pb, 0xBB)] {
            for seq in 0..2u64 {
                let lease = pool.lease_model(fp, &mut rng);
                assert!(!lease.was_dry, "model {fp:#x} seq {seq}");
                let (client, server, offline_bytes) =
                    offline_network_mt(plan, &mut session_rng(seed, seq), 1);
                assert_eq!(lease.session.offline_bytes, offline_bytes);
                let (bank_logits, _) =
                    run_inference(&lease.session.client, &lease.session.server, &input);
                let (inline_logits, _) = run_inference(&client, &server, &input);
                assert_eq!(bank_logits, inline_logits, "model {fp:#x} seq {seq}");
            }
        }
        assert_eq!(pool.fingerprint_drops(), 0);
        pool.shutdown();
    }

    #[test]
    fn spine_binding_check_catches_mixed_seed_material() {
        // Same-seed pieces bind; pieces from a dealer restarted with a
        // different base seed must be detected before assembly.
        let plan = tiny_plan();
        let spine_a = deal_spine(&plan, &mut session_rng(1, 0));
        let layers_a: Vec<ReluEntry> = (0..plan.n_relu_layers())
            .map(|li| deal_relu_layer_mt(&plan, &mut session_rng(1, 0), li, 1))
            .collect();
        assert!(spine_binds_layers(&plan, &spine_a, &layers_a));
        let layers_b: Vec<ReluEntry> = (0..plan.n_relu_layers())
            .map(|li| deal_relu_layer_mt(&plan, &mut session_rng(2, 0), li, 1))
            .collect();
        assert!(!spine_binds_layers(&plan, &spine_a, &layers_b));
    }

    #[test]
    fn banks_never_overshoot_target() {
        // Claim accounting bounds every bank at exactly `target` even
        // with many racing dealers (the old pool could overshoot to
        // target + n_dealers − 1).
        let pool = MaterialPool::start(tiny_plan(), 3, 4, 11);
        let mut rng = Rng::new(4);
        for _ in 0..3 {
            pool.wait_ready(3);
            assert_eq!(pool.banked(), 3);
            for (b, depth) in pool.bank_depths().into_iter().enumerate() {
                assert!(depth <= 3, "bank {b} overshot: {depth}");
            }
            let _ = pool.lease(&mut rng);
        }
        pool.shutdown();
    }

    #[test]
    fn wait_ready_returns_on_stop_with_dead_dealer() {
        // A remote source that never connects must not hang warmup: once
        // stop() is called, wait_ready returns instead of waiting on the
        // ready condvar forever.
        let connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync> =
            Arc::new(|| Err(crate::util::error::Error::msg("dealer unreachable")));
        let pool = MaterialPool::start_with_source(
            tiny_plan(),
            2,
            1,
            5,
            RefillSource::Remote { connect, batch: 2 },
            None,
            1,
        );
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| pool.wait_ready(1));
            std::thread::sleep(Duration::from_millis(100));
            pool.stop();
            waiter.join().expect("wait_ready returned after stop");
        });
        assert_eq!(pool.banked(), 0);
        pool.shutdown();
    }

    #[test]
    fn remote_refill_source_fills_bank() {
        // The deployment shape: material produced by a dealer "process"
        // (in-memory channel here), streamed in layer-granularly over
        // the wire codec, and banked per layer — with latency/bytes and
        // bank depths recorded.
        let plan = tiny_plan();
        let metrics = Arc::new(Metrics::default());
        let registry = ModelRegistry::single(plan.clone(), 77);
        let reg_c = registry.clone();
        let connect: Arc<dyn Fn() -> Result<RemoteDealer> + Send + Sync> = Arc::new(move || {
            let (chan, _dealer_thread) =
                crate::wire::dealer::spawn_mem_dealer_multi(reg_c.clone(), 77, 1);
            RemoteDealer::connect(chan, reg_c.clone())
        });
        let pool = MaterialPool::start_multi(
            registry,
            3,
            1,
            RefillSource::Remote { connect, batch: 2 },
            Some(metrics.clone()),
            1,
        );
        pool.wait_ready(3);
        let mut rng = Rng::new(2);
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry);
        assert!(lease.session.offline_bytes > 0);
        assert!(pool.produced() >= 3);
        assert_eq!(pool.fingerprint_drops(), 0);
        let snap = metrics.snapshot();
        assert!(snap.remote_refills >= 1, "refill rounds recorded");
        assert!(snap.remote_sessions >= 3, "sessions' worth (spines) recorded");
        assert!(snap.layer_entries >= 6, "per-layer units recorded");
        assert!(snap.bytes_offline_wire > 0, "wire bytes recorded");
        assert!(snap.remote_refill_mean_us > 0.0, "fetch latency recorded");
        assert_eq!(snap.bank_depths.len(), 2, "spine bank + one relu bank");
        pool.shutdown();
    }

    #[test]
    fn inline_deals_record_throughput() {
        // tiny_plan has one ReLU layer of 4 → 4 ReLUs per session.
        let metrics = Arc::new(Metrics::default());
        let pool = MaterialPool::start_with_source(
            tiny_plan(),
            3,
            2,
            11,
            RefillSource::Inline,
            Some(metrics.clone()),
            2,
        );
        pool.wait_ready(3);
        let snap = metrics.snapshot();
        assert!(snap.deal_relus >= 12, "relus recorded: {}", snap.deal_relus);
        assert!(snap.deal_relus_per_s > 0.0, "throughput recorded");
        pool.shutdown();
    }

    #[test]
    fn refill_after_lease() {
        let pool = MaterialPool::start(tiny_plan(), 2, 1, 9);
        pool.wait_ready(2);
        let mut rng = Rng::new(4);
        let _ = pool.lease(&mut rng);
        // Dealer should replenish toward the target.
        pool.wait_ready(2);
        assert!(pool.banked() >= 1);
        assert!(pool.produced() >= 3);
        pool.shutdown();
    }
}

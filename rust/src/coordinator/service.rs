//! The assembled PI service: batcher thread + worker pool + per-model
//! material bank, fronted by a submit/await handle that routes each
//! request to a registered model.
//!
//! Submission is **bounded and non-panicking**: the ingress queue is a
//! `sync_channel(max_queue)` admitted with `try_send`, so a caller sees
//! [`SubmitError::QueueFull`] instead of unbounded memory growth, and a
//! stopped service surfaces as [`SubmitError::Stopped`] /
//! a recv error on the [`ResponseHandle`] — never an `expect` panic.
//! Completion is a [`ResponseHandle`] with both blocking (`recv`) and
//! nonblocking (`try_recv`) paths; the latter is what lets the
//! [`crate::net::reactor`] poll thousands of in-flight inferences from
//! one thread.

use super::batcher::{next_model_batches, BatchPolicy, ModelBatch};
use super::metrics::Metrics;
use super::pool::{DealerEndpoint, MaterialPool, PoolTuning, RefillSource};
use super::registry::{model_base_seed, ModelRegistry};
use super::router::{spawn_workers, Request, Response};
use crate::ensure;
use crate::field::Fp;
use crate::protocol::server::NetworkPlan;
use crate::util::error::{Error, Result};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service configuration (fleet-wide; per-model knobs live in
/// [`ModelConfig`]).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    pub pool_target: usize,
    pub pool_dealers: usize,
    /// Threads each inline deal fans its garble and triple columns
    /// across (the column-wise offline schedule; material is
    /// thread-count-invariant).
    pub deal_threads: usize,
    pub batch: BatchPolicy,
    /// Root seed: the single-model wrapper pins its model's dealing
    /// namespace to exactly this value; [`PiService::start_multi`]
    /// derives per-model namespaces from it
    /// ([`model_base_seed`]) unless a [`ModelConfig`] overrides.
    pub seed: u64,
    /// When non-empty, the material pool refills from a **fleet** of
    /// standalone dealers at these TCP addresses
    /// ([`crate::wire::dealer`]) instead of dealing inline, streaming
    /// material layer by layer for every registered model. Claimed
    /// seq-ranges are partitioned across the live links, stale claims
    /// are work-stolen by idle links, and a dead dealer's claims are
    /// handed off — see [`super::pool`]. Refill latency, bytes-on-wire,
    /// and per-bank depths land in [`Metrics`], labeled per model and
    /// per link. Every dealer must serve (at least) every model
    /// registered here — weight digests included — or its handshake is
    /// rejected (and, since all links share one claim ledger, every
    /// dealer must run the same registry base seeds).
    pub dealer_addrs: Vec<String>,
    /// Pre-shared key for AES-128-CMAC authenticated dealer framing
    /// ([`crate::wire::auth`]); `None` runs plain CRC framing. Must
    /// match the key the dealers were started with — disagreement fails
    /// each link closed at its handshake.
    pub dealer_psk: Option<[u8; 16]>,
    /// Per-layer entries fetched per remote refill round trip.
    pub refill_batch: usize,
    /// Age (ms) after which an idle fleet link may steal another link's
    /// outstanding claim ([`PoolTuning::steal_after`]).
    pub steal_after_ms: u64,
    /// Half-life (ms) of the per-model lease-rate EWMA behind the
    /// traffic-adaptive refill weights
    /// ([`PoolTuning::demand_half_life`]).
    pub demand_half_life_ms: u64,
    /// Bound on the ingress queue: [`PiService::submit_to`] admits with
    /// `try_send` against a channel of this capacity and reports
    /// [`SubmitError::QueueFull`] above it — in-process callers get the
    /// same backpressure contract the network admission controller gives
    /// remote clients.
    pub max_queue: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            pool_target: 16,
            pool_dealers: 2,
            deal_threads: 1,
            batch: BatchPolicy::default(),
            seed: 0xC1CA,
            dealer_addrs: Vec::new(),
            dealer_psk: None,
            refill_batch: 4,
            steal_after_ms: 1000,
            demand_half_life_ms: 10_000,
            max_queue: 1024,
        }
    }
}

/// Why a submission was not queued. `QueueFull` and `Stopped` are
/// backpressure/lifecycle conditions a serving front end turns into
/// explicit `Busy`/`Error` frames; `UnknownModel` is a caller bug.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The fingerprint is not registered with this service.
    UnknownModel(u64),
    /// The bounded ingress queue is at capacity — retry later.
    QueueFull { capacity: usize },
    /// The service has been halted or shut down.
    Stopped,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::UnknownModel(fp) => {
                write!(f, "model {fp:#018x} is not registered with this service")
            }
            SubmitError::QueueFull { capacity } => {
                write!(f, "ingress queue full ({capacity} requests)")
            }
            SubmitError::Stopped => write!(f, "service is stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

impl From<SubmitError> for Error {
    fn from(e: SubmitError) -> Self {
        Error::msg(e)
    }
}

/// Completion handle for one submitted inference. Blocking callers use
/// [`Self::recv`]; the reactor polls [`Self::try_recv`] so an in-flight
/// inference never pins a thread. A dead service (halted, or its worker
/// fabric gone) surfaces as an `Err`, not a panic.
pub struct ResponseHandle {
    rx: Receiver<Response>,
}

impl ResponseHandle {
    /// Block until the response arrives. `Err` if the service stopped
    /// before responding.
    pub fn recv(&self) -> Result<Response> {
        self.rx.recv().map_err(|_| Error::msg("service stopped before responding"))
    }

    /// Nonblocking poll: `Ok(Some)` on arrival, `Ok(None)` while in
    /// flight, `Err` if the service stopped before responding.
    pub fn try_recv(&self) -> Result<Option<Response>> {
        match self.rx.try_recv() {
            Ok(r) => Ok(Some(r)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                Err(Error::msg("service stopped before responding"))
            }
        }
    }
}

/// Per-model configuration for [`PiService::start_multi`].
#[derive(Clone, Copy, Debug)]
pub struct ModelConfig {
    /// Dealing base seed for this model's seq namespace. `None` derives
    /// it from the service seed and the plan fingerprint
    /// ([`model_base_seed`]), which keeps any two models' namespaces
    /// disjoint by construction.
    pub base_seed: Option<u64>,
    /// Relative demand rate (> 0): scales this model's bank deficits in
    /// the refill scheduler, so the pool pre-deals material roughly in
    /// proportion to expected traffic.
    pub demand: f64,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self { base_seed: None, demand: 1.0 }
    }
}

/// A running PI service.
pub struct PiService {
    /// Bounded intake; `None` once halted (submissions then report
    /// [`SubmitError::Stopped`]).
    ingress: Mutex<Option<SyncSender<Request>>>,
    max_queue: usize,
    pub metrics: Arc<Metrics>,
    pub pool: Arc<MaterialPool>,
    registry: Arc<ModelRegistry>,
    next_id: AtomicU64,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl PiService {
    /// Start the service for a single network plan — a thin wrapper over
    /// [`Self::start_multi`] that pins the model's dealing namespace to
    /// `cfg.seed`, preserving bit-identity of every dealt byte with the
    /// pre-registry single-model service for the same `(seed, plan)`.
    pub fn start(plan: Arc<NetworkPlan>, cfg: ServiceConfig) -> Self {
        let seed = cfg.seed;
        Self::start_multi(
            vec![(plan, ModelConfig { base_seed: Some(seed), demand: 1.0 })],
            cfg,
        )
        .expect("single-plan service")
    }

    /// Start the service for several network plans at once: one material
    /// shard, one seq namespace, one metrics row per model, all served
    /// by one batcher/worker/dealer fabric. Fails on an empty model
    /// list, duplicate plans, or invalid per-model config.
    pub fn start_multi(
        models: Vec<(Arc<NetworkPlan>, ModelConfig)>,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        ensure!(!models.is_empty(), "start_multi needs at least one model");
        cfg.batch.validate()?;
        ensure!(cfg.max_queue >= 1, "max_queue must be >= 1 (got 0)");
        let mut registry = ModelRegistry::new();
        for (plan, mc) in models {
            let manifest = crate::wire::codec::SessionManifest::of_plan(&plan);
            let base_seed =
                mc.base_seed.unwrap_or_else(|| model_base_seed(cfg.seed, manifest.fingerprint));
            registry.register_with(plan, manifest, base_seed, mc.demand)?;
        }
        let registry = Arc::new(registry);

        let metrics = Arc::new(Metrics::default());
        let source = if cfg.dealer_addrs.is_empty() {
            RefillSource::Inline
        } else {
            let endpoints: Vec<DealerEndpoint> = cfg
                .dealer_addrs
                .iter()
                .map(|addr| DealerEndpoint::tcp(addr, registry.clone(), cfg.dealer_psk))
                .collect();
            RefillSource::remote(endpoints, cfg.refill_batch)
        };
        let tuning = PoolTuning {
            steal_after: Duration::from_millis(cfg.steal_after_ms.max(1)),
            demand_half_life: Duration::from_millis(cfg.demand_half_life_ms.max(1)),
        };
        let pool = Arc::new(MaterialPool::start_multi_tuned(
            registry.clone(),
            cfg.pool_target,
            cfg.pool_dealers,
            source,
            Some(metrics.clone()),
            cfg.deal_threads,
            tuning,
        ));

        // Bounded intake: submit_to admits with try_send, so the queue
        // can never hold more than max_queue requests and overload is an
        // explicit QueueFull at the submitter, not unbounded memory.
        let (ingress, ingress_rx): (SyncSender<Request>, Receiver<Request>) =
            sync_channel(cfg.max_queue);
        let (batch_tx, batch_rx): (Sender<ModelBatch>, Receiver<ModelBatch>) = channel();
        let policy = cfg.batch;
        let batcher_metrics = metrics.clone();
        let batcher = std::thread::spawn(move || {
            while let Some(batches) = next_model_batches(&ingress_rx, policy) {
                // Keep the ingress-depth gauge honest: these requests
                // left the bounded queue for dispatch.
                let pulled: u64 = batches.iter().map(|b| b.requests.len() as u64).sum();
                batcher_metrics.ingress_depth.fetch_sub(pulled, Ordering::Relaxed);
                for batch in batches {
                    if batch_tx.send(batch).is_err() {
                        return;
                    }
                }
            }
        });
        let workers =
            spawn_workers(cfg.workers, batch_rx, pool.clone(), metrics.clone(), cfg.seed ^ 0x77);

        Ok(Self {
            ingress: Mutex::new(Some(ingress)),
            max_queue: cfg.max_queue,
            metrics,
            pool,
            registry,
            next_id: AtomicU64::new(0),
            batcher: Some(batcher),
            workers,
        })
    }

    /// Fingerprints of the served models, in registration order (index 0
    /// is the default model of [`Self::submit`]/[`Self::infer`]).
    pub fn models(&self) -> Vec<u64> {
        self.registry.fingerprints()
    }

    /// Block until every model's bank holds at least `n` sessions
    /// (warmup).
    pub fn warmup(&self, n: usize) {
        self.pool.wait_ready(n);
    }

    /// Submit one inference to a registered model; returns a completion
    /// handle, or a [`SubmitError`] when the fingerprint is unknown
    /// (validated here so the worker path can trust every queued
    /// request), the bounded queue is full, or the service is stopped.
    /// Never blocks and never panics.
    pub fn submit_to(
        &self,
        model: u64,
        input: Vec<Fp>,
    ) -> std::result::Result<ResponseHandle, SubmitError> {
        if self.registry.get(model).is_none() {
            return Err(SubmitError::UnknownModel(model));
        }
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, model, input, enqueued: Instant::now(), reply: tx };
        let guard = self.ingress.lock().unwrap();
        let Some(sender) = guard.as_ref() else {
            return Err(SubmitError::Stopped);
        };
        match sender.try_send(req) {
            Ok(()) => {
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.metrics.ingress_depth.fetch_add(1, Ordering::Relaxed);
                Ok(ResponseHandle { rx })
            }
            Err(TrySendError::Full(_)) => {
                Err(SubmitError::QueueFull { capacity: self.max_queue })
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Stopped),
        }
    }

    /// Submit one inference to the first registered model (single-model
    /// convenience); returns a completion handle.
    pub fn submit(&self, input: Vec<Fp>) -> std::result::Result<ResponseHandle, SubmitError> {
        let model = self.registry.entries()[0].fingerprint();
        self.submit_to(model, input)
    }

    /// Submit to a model and wait (convenience). `Err` on submission
    /// rejection or if the service stops before responding.
    pub fn infer_on(&self, model: u64, input: Vec<Fp>) -> Result<Response> {
        self.submit_to(model, input)?.recv()
    }

    /// Submit to the default model and wait (convenience).
    pub fn infer(&self, input: Vec<Fp>) -> Result<Response> {
        self.submit(input)?.recv()
    }

    /// Stop intake without consuming the handle: subsequent submissions
    /// report [`SubmitError::Stopped`], queued work drains, the pool's
    /// dealer threads stop. Shared holders (e.g. a reactor's `Arc`) can
    /// call this; the owner still runs [`Self::shutdown`] to join.
    /// Idempotent.
    pub fn halt(&self) {
        self.ingress.lock().unwrap().take();
        self.pool.stop();
    }

    /// Graceful shutdown: stop intake, drain workers, stop dealers.
    pub fn shutdown(mut self) {
        self.halt();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        match Arc::try_unwrap(self.pool) {
            Ok(pool) => pool.shutdown(),
            Err(_) => { /* metrics holder still alive; dealers die with process */ }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::{FaultMode, ReluVariant};
    use crate::protocol::linear::{LinearOp, Matrix};
    use crate::util::Rng;

    fn plan(variant: ReluVariant) -> Arc<NetworkPlan> {
        let mut rng = Rng::new(1);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 5, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(linears, variant))
    }

    fn oracle(p: &NetworkPlan, input: &[Fp]) -> Vec<Fp> {
        let l0 = &p.linears[0];
        let l1 = &p.linears[1];
        let mid: Vec<Fp> =
            l0.apply(input).iter().map(|&v| crate::field::relu_exact(v)).collect();
        l1.apply(&mid)
    }

    #[test]
    fn serve_roundtrip_with_correct_results() {
        let p = plan(ReluVariant::TruncatedSign { k: 4, mode: FaultMode::PosZero });
        let svc = PiService::start(p.clone(), ServiceConfig {
            workers: 2,
            pool_target: 8,
            pool_dealers: 2,
            ..Default::default()
        });
        svc.warmup(4);
        let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(1000 + i)).collect();
        let want = oracle(&p, &input);
        for _ in 0..6 {
            let resp = svc.infer(input.clone()).unwrap();
            assert_eq!(resp.logits, want);
            assert!(resp.online_us > 0);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.completed, 6);
        assert!(snap.bytes_online > 0);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions() {
        let svc = PiService::start(plan(ReluVariant::BaselineRelu), ServiceConfig {
            workers: 3,
            pool_target: 8,
            pool_dealers: 2,
            ..Default::default()
        });
        let rxs: Vec<_> = (0..12)
            .map(|i| {
                svc.submit((0..6).map(|j| Fp::from_i64((i * 10 + j) as i64)).collect())
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.logits.len(), 3);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.completed, 12);
        assert_eq!(snap.ingress_queue_depth, 0, "gauge drains with the queue");
        svc.shutdown();
    }

    #[test]
    fn halted_service_errors_cleanly_not_panics() {
        let svc = PiService::start(plan(ReluVariant::BaselineRelu), ServiceConfig {
            workers: 1,
            pool_target: 2,
            pool_dealers: 1,
            ..Default::default()
        });
        let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(100 + i)).collect();
        // Alive: a submission round-trips.
        assert!(svc.infer(input.clone()).is_ok());
        // Kill the service out from under its callers.
        svc.halt();
        svc.halt(); // idempotent
        assert_eq!(svc.submit(input.clone()).unwrap_err(), SubmitError::Stopped);
        assert_eq!(
            svc.submit_to(svc.models()[0], input.clone()).unwrap_err(),
            SubmitError::Stopped
        );
        let err = svc.infer(input).unwrap_err();
        assert!(err.to_string().contains("stopped"), "{err}");
        svc.shutdown();
    }

    #[test]
    fn bounded_ingress_sheds_with_queue_full() {
        // Capacity-1 ingress: a tight submission burst must hit the
        // bounded queue faster than the batcher drains it and surface
        // QueueFull (the try_send admission contract) instead of growing
        // without bound.
        let svc = PiService::start(plan(ReluVariant::BaselineRelu), ServiceConfig {
            workers: 1,
            pool_target: 2,
            pool_dealers: 1,
            max_queue: 1,
            ..Default::default()
        });
        let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(100 + i)).collect();
        let mut handles = Vec::new();
        let mut saw_full = false;
        for _ in 0..200_000 {
            match svc.submit(input.clone()) {
                Ok(h) => handles.push(h),
                Err(SubmitError::QueueFull { capacity }) => {
                    assert_eq!(capacity, 1);
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert!(saw_full, "200k burst submissions never saw the capacity-1 queue full");
        // Everything that was admitted completes normally.
        for h in handles {
            assert_eq!(h.recv().unwrap().logits.len(), 3);
        }
        svc.shutdown();
    }

    #[test]
    fn zero_max_queue_rejected_at_start() {
        let cfg = ServiceConfig { max_queue: 0, ..Default::default() };
        let models = vec![(plan(ReluVariant::BaselineRelu), ModelConfig::default())];
        assert!(PiService::start_multi(models, cfg).is_err());
    }

    #[test]
    fn start_multi_rejects_zero_batch_size() {
        let cfg = ServiceConfig {
            batch: BatchPolicy { max_size: 0, ..Default::default() },
            ..Default::default()
        };
        let models = vec![(plan(ReluVariant::BaselineRelu), ModelConfig::default())];
        let res = PiService::start_multi(models, cfg);
        assert!(res.is_err(), "max_size 0 must be rejected at startup");
    }

    #[test]
    fn multi_model_service_routes_per_model() {
        // Two same-shaped models with different variants served side by
        // side: each request's answer matches the oracle of the model it
        // named, and the metrics split per model.
        let exact = plan(ReluVariant::BaselineRelu);
        let circa = plan(ReluVariant::TruncatedSign { k: 4, mode: FaultMode::PosZero });
        let svc = PiService::start_multi(
            vec![
                (exact.clone(), ModelConfig::default()),
                (circa.clone(), ModelConfig { base_seed: None, demand: 2.0 }),
            ],
            ServiceConfig { workers: 2, pool_target: 6, pool_dealers: 2, ..Default::default() },
        )
        .unwrap();
        let models = svc.models();
        assert_eq!(models.len(), 2);
        svc.warmup(2);

        // Both plans share weights (seed 1), so the exact-ReLU oracle is
        // the same function; what differs per model is the protocol
        // variant. The k=4 input magnitudes keep trunc faults away.
        let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(1500 + i)).collect();
        let rx_a: Vec<_> =
            (0..3).map(|_| svc.submit_to(models[0], input.clone()).unwrap()).collect();
        let rx_b: Vec<_> =
            (0..3).map(|_| svc.submit_to(models[1], input.clone()).unwrap()).collect();
        for rx in rx_a {
            let r = rx.recv().unwrap();
            assert_eq!(r.model, models[0]);
            assert_eq!(r.logits, oracle(&exact, &input));
        }
        for rx in rx_b {
            let r = rx.recv().unwrap();
            assert_eq!(r.model, models[1]);
            assert_eq!(r.logits, oracle(&circa, &input));
        }

        // Unknown model is rejected at submission.
        assert!(svc.submit_to(models[0] ^ 0xDEAD, input).is_err());

        let snap = svc.metrics.snapshot();
        assert_eq!(snap.completed, 6);
        assert_eq!(snap.models.len(), 2);
        for row in &snap.models {
            assert_eq!(row.completed, 3, "model {:#x}", row.fingerprint);
        }
        svc.shutdown();
    }
}

//! The assembled PI service: batcher thread + worker pool + material
//! bank, fronted by a submit/await handle.

use super::batcher::{next_batch, BatchPolicy};
use super::metrics::Metrics;
use super::pool::{MaterialPool, RefillSource};
use super::router::{spawn_workers, Request, Response};
use crate::field::Fp;
use crate::protocol::server::NetworkPlan;
use crate::wire::dealer::RemoteDealer;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    pub pool_target: usize,
    pub pool_dealers: usize,
    /// Threads each inline deal fans its garble columns across (the
    /// column-wise offline schedule; material is thread-count-invariant).
    pub deal_threads: usize,
    pub batch: BatchPolicy,
    pub seed: u64,
    /// When set, the material pool refills from a standalone dealer at
    /// this TCP address ([`crate::wire::dealer`]) instead of dealing
    /// inline, streaming material layer by layer; refill latency,
    /// bytes-on-wire, and per-bank depths land in [`Metrics`].
    pub dealer_addr: Option<String>,
    /// Per-layer entries fetched per remote refill round trip.
    pub refill_batch: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            pool_target: 16,
            pool_dealers: 2,
            deal_threads: 1,
            batch: BatchPolicy::default(),
            seed: 0xC1CA,
            dealer_addr: None,
            refill_batch: 4,
        }
    }
}

/// A running PI service.
pub struct PiService {
    ingress: Sender<Request>,
    pub metrics: Arc<Metrics>,
    pub pool: Arc<MaterialPool>,
    next_id: AtomicU64,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl PiService {
    /// Start the service for a network plan.
    pub fn start(plan: Arc<NetworkPlan>, cfg: ServiceConfig) -> Self {
        let metrics = Arc::new(Metrics::default());
        let source = match &cfg.dealer_addr {
            None => RefillSource::Inline,
            Some(addr) => {
                let addr = addr.clone();
                let plan = plan.clone();
                RefillSource::Remote {
                    connect: Arc::new(move || RemoteDealer::connect_tcp(&addr, plan.clone())),
                    batch: cfg.refill_batch,
                }
            }
        };
        let pool = Arc::new(MaterialPool::start_with_source(
            plan,
            cfg.pool_target,
            cfg.pool_dealers,
            cfg.seed,
            source,
            Some(metrics.clone()),
            cfg.deal_threads,
        ));

        let (ingress, ingress_rx): (Sender<Request>, Receiver<Request>) = channel();
        let (batch_tx, batch_rx) = channel();
        let policy = cfg.batch;
        let batcher = std::thread::spawn(move || {
            while let Some(batch) = next_batch(&ingress_rx, policy) {
                if batch_tx.send(batch).is_err() {
                    return;
                }
            }
        });
        let workers =
            spawn_workers(cfg.workers, batch_rx, pool.clone(), metrics.clone(), cfg.seed ^ 0x77);

        Self {
            ingress,
            metrics,
            pool,
            next_id: AtomicU64::new(0),
            batcher: Some(batcher),
            workers,
        }
    }

    /// Block until the bank holds at least `n` sessions (warmup).
    pub fn warmup(&self, n: usize) {
        self.pool.wait_ready(n);
    }

    /// Submit one inference; returns a receiver for the response.
    pub fn submit(&self, input: Vec<Fp>) -> Receiver<Response> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let _ = self.ingress.send(Request { id, input, enqueued: Instant::now(), reply: tx });
        rx
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, input: Vec<Fp>) -> Response {
        self.submit(input).recv().expect("service alive")
    }

    /// Graceful shutdown: stop intake, drain workers, stop dealers.
    pub fn shutdown(mut self) {
        drop(self.ingress);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        match Arc::try_unwrap(self.pool) {
            Ok(pool) => pool.shutdown(),
            Err(_) => { /* metrics holder still alive; dealers die with process */ }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::{FaultMode, ReluVariant};
    use crate::protocol::linear::{LinearOp, Matrix};
    use crate::util::Rng;

    fn plan(variant: ReluVariant) -> Arc<NetworkPlan> {
        let mut rng = Rng::new(1);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 5, 10, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(linears, variant))
    }

    #[test]
    fn serve_roundtrip_with_correct_results() {
        let p = plan(ReluVariant::TruncatedSign { k: 4, mode: FaultMode::PosZero });
        // Plaintext oracle.
        let oracle = |input: &[Fp]| -> Vec<Fp> {
            let l0 = &p.linears[0];
            let l1 = &p.linears[1];
            let mid: Vec<Fp> =
                l0.apply(input).iter().map(|&v| crate::field::relu_exact(v)).collect();
            l1.apply(&mid)
        };
        let svc = PiService::start(p.clone(), ServiceConfig {
            workers: 2,
            pool_target: 8,
            pool_dealers: 2,
            ..Default::default()
        });
        svc.warmup(4);
        let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(1000 + i)).collect();
        let want = oracle(&input);
        for _ in 0..6 {
            let resp = svc.infer(input.clone());
            assert_eq!(resp.logits, want);
            assert!(resp.online_us > 0);
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.completed, 6);
        assert!(snap.bytes_online > 0);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions() {
        let svc = PiService::start(plan(ReluVariant::BaselineRelu), ServiceConfig {
            workers: 3,
            pool_target: 8,
            pool_dealers: 2,
            ..Default::default()
        });
        let rxs: Vec<_> = (0..12)
            .map(|i| svc.submit((0..6).map(|j| Fp::from_i64((i * 10 + j) as i64)).collect()))
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.logits.len(), 3);
        }
        assert_eq!(svc.metrics.snapshot().completed, 12);
        svc.shutdown();
    }
}

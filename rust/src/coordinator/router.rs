//! Worker pool: runs the 2-party online protocol for leased sessions,
//! leasing each model-homogeneous batch from that model's pool shard.

use super::batcher::ModelBatch;
use super::metrics::Metrics;
use super::pool::MaterialPool;
use crate::field::Fp;
use crate::protocol::server::{run_inference, run_inference_multi};
use crate::util::{Rng, Timer};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// One inference request, addressed to a registered model.
pub struct Request {
    pub id: u64,
    /// Manifest fingerprint of the plan this request runs on (validated
    /// at submission — see `PiService::submit_to`).
    pub model: u64,
    pub input: Vec<Fp>,
    pub enqueued: Instant,
    /// Where to deliver the response.
    pub reply: Sender<Response>,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// The model that served this request.
    pub model: u64,
    pub logits: Vec<Fp>,
    pub queue_us: u64,
    pub online_us: u64,
    pub bytes: u64,
    pub served_from_bank: bool,
}

/// Spawn `n_workers` threads consuming model-homogeneous request
/// batches from `rx`.
pub fn spawn_workers(
    n_workers: usize,
    rx: Receiver<ModelBatch>,
    pool: Arc<MaterialPool>,
    metrics: Arc<Metrics>,
    seed: u64,
) -> Vec<JoinHandle<()>> {
    let rx = Arc::new(Mutex::new(rx));
    (0..n_workers.max(1))
        .map(|w| {
            let rx = rx.clone();
            let pool = pool.clone();
            let metrics = metrics.clone();
            let mut rng = Rng::new(seed ^ (w as u64).wrapping_mul(0xA24BAED4963EE407));
            std::thread::spawn(move || loop {
                let batch = {
                    let guard = rx.lock().unwrap();
                    match guard.recv() {
                        Ok(b) => b,
                        Err(_) => return,
                    }
                };
                let model = batch.model;
                let bsize = batch.requests.len();
                if bsize == 0 {
                    continue;
                }
                metrics.record_batch_size(model, bsize as u64);
                if bsize == 1 {
                    // Per-request path: one leased session, the plain
                    // two-thread protocol driver.
                    for req in batch.requests {
                        let queue_us = req.enqueued.elapsed().as_micros() as u64;
                        let lease = pool.lease_model(model, &mut rng);
                        if lease.was_dry {
                            // Counter + inline-deal latency histogram: a
                            // dry bank shows up as measurable tail
                            // latency. The deal also counts toward
                            // dealing throughput.
                            metrics.record_dry_deal(model, lease.deal_us);
                            metrics
                                .record_deal(model, lease.session.n_relus() as u64, lease.deal_us);
                        }
                        let t = Timer::new();
                        let (logits, stats) = run_inference(
                            &lease.session.client,
                            &lease.session.server,
                            &req.input,
                        );
                        let online_us = t.elapsed_us();
                        let bytes = stats.bytes_to_client + stats.bytes_to_server;
                        metrics.record(model, queue_us, online_us, bytes);
                        metrics.record_batch_req(model, online_us);
                        let _ = req.reply.send(Response {
                            id: req.id,
                            model,
                            logits,
                            queue_us,
                            online_us,
                            bytes,
                            served_from_bank: !lease.was_dry,
                        });
                    }
                    continue;
                }
                // Batched walk: lease one session per request from the
                // model's shard, then execute the whole ModelBatch as a
                // single cross-request strided inference.
                let queue_us: Vec<u64> = batch
                    .requests
                    .iter()
                    .map(|r| r.enqueued.elapsed().as_micros() as u64)
                    .collect();
                let leases: Vec<_> = (0..bsize)
                    .map(|_| {
                        let lease = pool.lease_model(model, &mut rng);
                        if lease.was_dry {
                            metrics.record_dry_deal(model, lease.deal_us);
                            metrics
                                .record_deal(model, lease.session.n_relus() as u64, lease.deal_us);
                        }
                        lease
                    })
                    .collect();
                let sessions: Vec<_> =
                    leases.iter().map(|l| (&l.session.client, &l.session.server)).collect();
                let inputs: Vec<&[Fp]> =
                    batch.requests.iter().map(|r| r.input.as_slice()).collect();
                let t = Timer::new();
                let (all_logits, stats) = run_inference_multi(&sessions, &inputs, 1);
                // Every request experienced the full batch wall; the
                // amortized share and the exact per-request byte
                // footprint (identical across a homogeneous batch) feed
                // the batch-attribution histograms.
                let online_us = t.elapsed_us();
                let bytes = stats.bytes_to_client + stats.bytes_to_server;
                let per_req_bytes = bytes / bsize as u64;
                let amortized_us = online_us / bsize as u64;
                let replies = batch.requests.into_iter().zip(all_logits).zip(queue_us).zip(&leases);
                for (((req, logits), qus), lease) in replies {
                    metrics.record(model, qus, online_us, per_req_bytes);
                    metrics.record_batch_req(model, amortized_us);
                    let _ = req.reply.send(Response {
                        id: req.id,
                        model,
                        logits,
                        queue_us: qus,
                        online_us,
                        bytes: per_req_bytes,
                        served_from_bank: !lease.was_dry,
                    });
                }
            })
        })
        .collect()
}

/// Convenience used by tests: a (sender, receiver) pair of the batch
/// channel type the router consumes.
pub fn batch_channel() -> (Sender<ModelBatch>, Receiver<ModelBatch>) {
    channel()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::spec::ReluVariant;
    use crate::protocol::linear::{LinearOp, Matrix};
    use crate::protocol::server::NetworkPlan;

    #[test]
    fn workers_serve_requests() {
        let mut rng = Rng::new(1);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        let plan = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));
        let pool = Arc::new(MaterialPool::start(plan, 4, 1, 2));
        let model = pool.registry().entries()[0].fingerprint();
        let metrics = Arc::new(Metrics::default());
        let (btx, brx) = batch_channel();
        let workers = spawn_workers(2, brx, pool.clone(), metrics.clone(), 3);

        let (rtx, rrx) = channel();
        let reqs: Vec<Request> = (0..4)
            .map(|id| Request {
                id,
                model,
                input: (0..6).map(|i| Fp::from_i64(100 + i)).collect(),
                enqueued: Instant::now(),
                reply: rtx.clone(),
            })
            .collect();
        btx.send(ModelBatch { model, requests: reqs }).unwrap();
        drop(btx);
        drop(rtx);
        let responses: Vec<Response> = rrx.iter().collect();
        assert_eq!(responses.len(), 4);
        for r in &responses {
            assert_eq!(r.logits.len(), 3);
            assert_eq!(r.model, model);
        }
        for w in workers {
            let _ = w.join();
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.completed, 4);
        assert_eq!(snap.models.len(), 1);
        assert_eq!(snap.models[0].fingerprint, model);
    }

    #[test]
    fn batched_walk_serves_whole_batch_with_correct_logits() {
        let mut rng = Rng::new(5);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(4, 6, 10, &mut rng)),
            Arc::new(Matrix::random(3, 4, 10, &mut rng)),
        ];
        let plan = Arc::new(NetworkPlan::unscaled(linears.clone(), ReluVariant::BaselineRelu));
        let pool = Arc::new(MaterialPool::start(plan, 8, 1, 6));
        let model = pool.registry().entries()[0].fingerprint();
        let metrics = Arc::new(Metrics::default());
        let (btx, brx) = batch_channel();
        // One worker + one 8-request batch ⇒ exactly one batched walk.
        let workers = spawn_workers(1, brx, pool, metrics.clone(), 7);

        let (rtx, rrx) = channel();
        let inputs: Vec<Vec<Fp>> = (0..8u64)
            .map(|r| (0..6).map(|i| Fp::from_i64(50 + 13 * r as i64 + i)).collect())
            .collect();
        let reqs: Vec<Request> = inputs
            .iter()
            .enumerate()
            .map(|(id, input)| Request {
                id: id as u64,
                model,
                input: input.clone(),
                enqueued: Instant::now(),
                reply: rtx.clone(),
            })
            .collect();
        btx.send(ModelBatch { model, requests: reqs }).unwrap();
        drop(btx);
        drop(rtx);
        let mut responses: Vec<Response> = rrx.iter().collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 8);
        for r in &responses {
            // BaselineRelu is exact: every request's logits must match
            // the plaintext forward pass on its own input.
            let mut y = inputs[r.id as usize].clone();
            y = linears[0].apply(&y);
            y = y.iter().map(|&v| crate::field::relu_exact(v)).collect();
            y = linears[1].apply(&y);
            assert_eq!(r.logits, y, "request {}", r.id);
            assert!(r.bytes > 0);
        }
        // All requests in one batch share the batch wall time.
        assert!(responses.iter().all(|r| r.online_us == responses[0].online_us));
        for w in workers {
            let _ = w.join();
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.completed, 8);
        assert!((snap.batch_size_mean - 8.0).abs() < 1e-9, "one 8-wide batch");
        assert!(snap.batch_size_max >= 8);
        assert!(snap.batch_req_p99_us <= snap.online_p99_us);
    }
}

//! Service metrics: counters + latency histograms.

use crate::util::stats::Histogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics sink (cheap atomics on the hot path; histograms behind
/// a short-critical-section mutex).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub pool_dry_events: AtomicU64,
    pub bytes_online: AtomicU64,
    /// Remote-dealer fetch round trips completed (layer-granular rounds
    /// included).
    pub remote_refills: AtomicU64,
    /// Sessions' worth of material delivered by remote refills (one per
    /// linear spine — every assembled session consumes exactly one).
    pub remote_sessions: AtomicU64,
    /// Per-layer units (ReLU layer batches + spines) delivered by
    /// remote layer-granular refills.
    pub layer_entries: AtomicU64,
    /// Offline material received over the wire (frame bytes included).
    pub bytes_offline_wire: AtomicU64,
    /// Latest per-bank staged depth gauge (index 0 = linear spines,
    /// `1 + li` = ReLU layer `li`), published by the material pool.
    bank_depths: Mutex<Vec<u64>>,
    /// ReLUs dealt by local offline deals (pool refill + dry leases).
    pub deal_relus: AtomicU64,
    /// Wall-clock time spent in those deals, µs, summed across pool
    /// dealer slots (NOT core-time: a deal fanned over `deal_threads`
    /// cores counts its wall time once, which is exactly how its speedup
    /// shows up in the throughput ratio).
    pub deal_wall_us: AtomicU64,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    online_us: Histogram,
    queue_us: Histogram,
    total_us: Histogram,
    /// Inline-deal latency of pool-dry leases — the offline-throughput
    /// shortfall as the request path actually pays it.
    dry_deal_us: Histogram,
    /// Latency of one remote-dealer fetch round trip (request → all
    /// sessions decoded).
    remote_refill_us: Histogram,
}

/// A snapshot for reporting.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub completed: u64,
    pub pool_dry_events: u64,
    pub bytes_online: u64,
    pub online_p50_us: u64,
    pub online_p99_us: u64,
    pub online_mean_us: f64,
    pub queue_mean_us: f64,
    pub total_p50_us: u64,
    pub total_p99_us: u64,
    pub dry_deal_mean_us: f64,
    pub dry_deal_p99_us: u64,
    pub remote_refills: u64,
    pub remote_sessions: u64,
    pub layer_entries: u64,
    pub bytes_offline_wire: u64,
    pub remote_refill_mean_us: f64,
    pub remote_refill_p99_us: u64,
    /// Latest per-bank staged depth (0 = linear spines, then one entry
    /// per ReLU layer). Empty until the pool publishes it.
    pub bank_depths: Vec<u64>,
    pub deal_relus: u64,
    /// Offline dealing throughput, ReLUs per second of dealer-slot wall
    /// time (0.0 before any deal is recorded). Scales with
    /// `deal_threads`: an intra-deal fan-out shortens the wall time of
    /// every deal, raising this number.
    pub deal_relus_per_s: f64,
}

impl Metrics {
    pub fn record(&self, queue_us: u64, online_us: u64, bytes: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.bytes_online.fetch_add(bytes, Ordering::Relaxed);
        let mut g = self.inner.lock().unwrap();
        g.queue_us.record_us(queue_us);
        g.online_us.record_us(online_us);
        g.total_us.record_us(queue_us + online_us);
    }

    /// Record a pool-dry lease: bumps the counter and feeds the measured
    /// inline-deal latency into its histogram, so pool-dry tail latency
    /// is visible (e.g. in `serve_pi`), not just its frequency.
    pub fn record_dry_deal(&self, deal_us: u64) {
        self.pool_dry_events.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap().dry_deal_us.record_us(deal_us);
    }

    /// Record one whole-session remote refill round trip: fetch latency,
    /// bytes that crossed the wire, and sessions delivered. Legacy
    /// counterpart of [`Self::record_layer_refill`] for callers driving
    /// `RemoteDealer::fetch` (the whole-`Session` round) directly — the
    /// pool's layer-granular refill path no longer uses it.
    pub fn record_remote_refill(&self, fetch_us: u64, bytes: u64, sessions: u64) {
        self.remote_refills.fetch_add(1, Ordering::Relaxed);
        self.remote_sessions.fetch_add(sessions, Ordering::Relaxed);
        self.bytes_offline_wire.fetch_add(bytes, Ordering::Relaxed);
        self.inner.lock().unwrap().remote_refill_us.record_us(fetch_us);
    }

    /// Record one layer-granular refill round trip: `entries` per-layer
    /// units fetched, of which `spines` were linear spines (the
    /// sessions'-worth counter — one spine per assembled session).
    pub fn record_layer_refill(&self, fetch_us: u64, bytes: u64, entries: u64, spines: u64) {
        self.remote_refills.fetch_add(1, Ordering::Relaxed);
        self.layer_entries.fetch_add(entries, Ordering::Relaxed);
        self.remote_sessions.fetch_add(spines, Ordering::Relaxed);
        self.bytes_offline_wire.fetch_add(bytes, Ordering::Relaxed);
        self.inner.lock().unwrap().remote_refill_us.record_us(fetch_us);
    }

    /// Publish the pool's per-bank staged depths (gauge semantics: the
    /// latest value wins).
    pub fn set_bank_depths(&self, depths: Vec<u64>) {
        *self.bank_depths.lock().unwrap() = depths;
    }

    /// Record one local offline deal: `relus` ReLUs' worth of material
    /// produced in `us` microseconds of wall time. Fed by the pool
    /// refill threads and by dry leases; the snapshot's
    /// [`Snapshot::deal_relus_per_s`] is the running aggregate.
    pub fn record_deal(&self, relus: u64, us: u64) {
        self.deal_relus.fetch_add(relus, Ordering::Relaxed);
        // Clamp to 1µs so a sub-microsecond deal (tiny test plans) still
        // registers time and the ratio stays finite.
        self.deal_wall_us.fetch_add(us.max(1), Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let deal_relus = self.deal_relus.load(Ordering::Relaxed);
        let deal_wall_us = self.deal_wall_us.load(Ordering::Relaxed);
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            pool_dry_events: self.pool_dry_events.load(Ordering::Relaxed),
            bytes_online: self.bytes_online.load(Ordering::Relaxed),
            online_p50_us: g.online_us.percentile_us(50.0),
            online_p99_us: g.online_us.percentile_us(99.0),
            online_mean_us: g.online_us.mean_us(),
            queue_mean_us: g.queue_us.mean_us(),
            total_p50_us: g.total_us.percentile_us(50.0),
            total_p99_us: g.total_us.percentile_us(99.0),
            dry_deal_mean_us: g.dry_deal_us.mean_us(),
            dry_deal_p99_us: g.dry_deal_us.percentile_us(99.0),
            remote_refills: self.remote_refills.load(Ordering::Relaxed),
            remote_sessions: self.remote_sessions.load(Ordering::Relaxed),
            layer_entries: self.layer_entries.load(Ordering::Relaxed),
            bytes_offline_wire: self.bytes_offline_wire.load(Ordering::Relaxed),
            bank_depths: self.bank_depths.lock().unwrap().clone(),
            remote_refill_mean_us: g.remote_refill_us.mean_us(),
            remote_refill_p99_us: g.remote_refill_us.percentile_us(99.0),
            deal_relus,
            deal_relus_per_s: if deal_wall_us == 0 {
                0.0
            } else {
                deal_relus as f64 * 1e6 / deal_wall_us as f64
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record(100, 1000, 64);
        m.record(200, 2000, 64);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.completed, 2);
        assert_eq!(s.bytes_online, 128);
        assert!(s.online_mean_us >= 1000.0);
        assert!(s.total_p99_us >= s.total_p50_us);
    }

    #[test]
    fn remote_refill_recorded() {
        let m = Metrics::default();
        let s0 = m.snapshot();
        assert_eq!(s0.remote_refills, 0);
        assert_eq!(s0.bytes_offline_wire, 0);
        m.record_remote_refill(2_000, 1_000_000, 4);
        m.record_remote_refill(4_000, 500_000, 2);
        let s = m.snapshot();
        assert_eq!(s.remote_refills, 2);
        assert_eq!(s.remote_sessions, 6);
        assert_eq!(s.bytes_offline_wire, 1_500_000);
        assert!((s.remote_refill_mean_us - 3_000.0).abs() < 1e-9);
        assert!(s.remote_refill_p99_us >= 4_000);
    }

    #[test]
    fn layer_refill_and_bank_depths_recorded() {
        let m = Metrics::default();
        assert!(m.snapshot().bank_depths.is_empty());
        m.record_layer_refill(1_000, 500_000, 3, 1);
        m.record_layer_refill(3_000, 250_000, 2, 0);
        m.set_bank_depths(vec![4, 2, 7]);
        let s = m.snapshot();
        assert_eq!(s.remote_refills, 2);
        assert_eq!(s.layer_entries, 5);
        assert_eq!(s.remote_sessions, 1);
        assert_eq!(s.bytes_offline_wire, 750_000);
        assert!((s.remote_refill_mean_us - 2_000.0).abs() < 1e-9);
        assert_eq!(s.bank_depths, vec![4, 2, 7]);
    }

    #[test]
    fn deal_throughput_recorded() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().deal_relus_per_s, 0.0, "no div-by-zero before first deal");
        m.record_deal(500, 250_000);
        m.record_deal(500, 250_000);
        let s = m.snapshot();
        assert_eq!(s.deal_relus, 1000);
        assert!((s.deal_relus_per_s - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn dry_deal_latency_recorded() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().dry_deal_mean_us, 0.0);
        m.record_dry_deal(5_000);
        m.record_dry_deal(15_000);
        let s = m.snapshot();
        assert_eq!(s.pool_dry_events, 2);
        assert!((s.dry_deal_mean_us - 10_000.0).abs() < 1e-9);
        assert!(s.dry_deal_p99_us >= 15_000);
    }
}

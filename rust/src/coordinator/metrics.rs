//! Service metrics: counters + latency histograms, with **per-model
//! labels** — every recorder takes the model fingerprint of the work it
//! measures, so a multi-model coordinator reports one row per served
//! plan (bank depths, refill counters, latency histograms) alongside
//! the fleet-wide aggregates — and, since the fleet-scheduler revision,
//! **per-dealer-link rows** (fetch throughput/latency, failures,
//! reconnects, steals both directions, late drops) registered by the
//! pool at start, plus per-model EWMA demand gauges showing what the
//! adaptive refill weights currently chase.

use crate::util::stats::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics sink (cheap atomics on the fleet-wide hot path;
/// histograms and the per-model table behind short-critical-section
/// mutexes).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub pool_dry_events: AtomicU64,
    pub bytes_online: AtomicU64,
    /// Requests currently queued in the bounded ingress channel (gauge:
    /// incremented on admit, decremented as the batcher drains) — the
    /// queue-depth signal the admission controller samples.
    pub ingress_depth: AtomicU64,
    /// Requests shed with an explicit `Busy` by admission control
    /// (bank-dry or queue-over-limit), fleet-wide.
    pub sheds: AtomicU64,
    /// Remote-dealer fetch round trips completed (layer-granular rounds
    /// included).
    pub remote_refills: AtomicU64,
    /// Sessions' worth of material delivered by remote refills (one per
    /// linear spine — every assembled session consumes exactly one).
    pub remote_sessions: AtomicU64,
    /// Per-layer units (ReLU layer batches + spines) delivered by
    /// remote layer-granular refills.
    pub layer_entries: AtomicU64,
    /// Offline material received over the wire (frame bytes included).
    pub bytes_offline_wire: AtomicU64,
    /// Remote units dropped at staging because their fingerprint tag
    /// named another model (the pool's cross-model staging guard).
    pub fp_mismatch_drops: AtomicU64,
    /// ReLUs dealt by local offline deals (pool refill + dry leases).
    pub deal_relus: AtomicU64,
    /// Wall-clock time spent in those deals, µs, summed across pool
    /// dealer slots (NOT core-time: a deal fanned over `deal_threads`
    /// cores counts its wall time once, which is exactly how its speedup
    /// shows up in the throughput ratio).
    pub deal_wall_us: AtomicU64,
    inner: Mutex<Inner>,
    /// Per-model rows, keyed by manifest fingerprint.
    per_model: Mutex<BTreeMap<u64, ModelStats>>,
    /// Per-dealer-link rows, indexed by the pool's link index
    /// (registered once by [`Self::register_links`]).
    links: Mutex<Vec<LinkStats>>,
}

#[derive(Default)]
struct Inner {
    online_us: Histogram,
    queue_us: Histogram,
    total_us: Histogram,
    /// Inline-deal latency of pool-dry leases — the offline-throughput
    /// shortfall as the request path actually pays it.
    dry_deal_us: Histogram,
    /// Latency of one remote-dealer fetch round trip (request → all
    /// units decoded).
    remote_refill_us: Histogram,
    /// Dispatch batch sizes (requests per batched walk; 1 = the
    /// per-request fallback path).
    batch_size: Histogram,
    /// Amortized per-request online time inside a batch (batch wall /
    /// batch size) — read against `online_us` (the full batch wall each
    /// request experiences) to see what batching buys per request.
    batch_req_us: Histogram,
}

/// One model's accumulating row.
#[derive(Default)]
struct ModelStats {
    completed: u64,
    bytes_online: u64,
    pool_dry_events: u64,
    sheds: u64,
    deal_relus: u64,
    deal_wall_us: u64,
    remote_refills: u64,
    remote_sessions: u64,
    layer_entries: u64,
    bytes_offline_wire: u64,
    online_us: Histogram,
    total_us: Histogram,
    batch_size: Histogram,
    batch_req_us: Histogram,
    /// Latest per-bank staged depth gauge (index 0 = linear spines,
    /// `1 + li` = ReLU layer `li`), published by the model's pool shard.
    bank_depths: Vec<u64>,
    /// Latest EWMA lease-rate score (gauge, published with the claim
    /// weights by the pool's fleet scheduler).
    demand_ewma: f64,
    /// Latest effective refill weight derived from the EWMA (gauge; the
    /// configured static demand until traffic warms the EWMA up).
    demand_weight: f64,
}

/// One dealer link's accumulating row.
#[derive(Default)]
struct LinkStats {
    label: String,
    /// Completed fetch round trips.
    fetches: u64,
    /// Per-layer units (layer batches + spines) staged from this link.
    units: u64,
    /// Wire bytes received on this link (frame overhead included).
    bytes: u64,
    /// Fetch/connect errors (each one abandons the link's claim and
    /// triggers reconnect-with-backoff).
    failures: u64,
    /// Successful reconnects after a failure.
    reconnects: u64,
    /// Claims this link stole from a slower link.
    steals: u64,
    /// Claims stolen *from* this link by an idle one.
    stolen_from: u64,
    /// Units this link produced after its claim had been stolen
    /// (discarded at staging — duplicated work, never duplicated banks).
    late_drop_units: u64,
    fetch_us: Histogram,
}

/// A per-dealer-link reporting row.
#[derive(Clone, Debug)]
pub struct LinkSnapshot {
    pub label: String,
    pub fetches: u64,
    pub units: u64,
    pub bytes: u64,
    pub failures: u64,
    pub reconnects: u64,
    pub steals: u64,
    pub stolen_from: u64,
    pub late_drop_units: u64,
    pub fetch_p50_us: u64,
    pub fetch_p99_us: u64,
    pub fetch_mean_us: f64,
}

/// A per-model reporting row.
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    pub fingerprint: u64,
    pub completed: u64,
    pub bytes_online: u64,
    pub pool_dry_events: u64,
    /// Requests for this model shed with `Busy` by admission control.
    pub sheds: u64,
    pub online_p50_us: u64,
    pub online_p99_us: u64,
    pub online_mean_us: f64,
    pub total_p50_us: u64,
    pub total_p99_us: u64,
    pub deal_relus: u64,
    pub deal_relus_per_s: f64,
    pub remote_refills: u64,
    pub remote_sessions: u64,
    pub layer_entries: u64,
    pub bytes_offline_wire: u64,
    pub batch_size_mean: f64,
    pub batch_req_p99_us: u64,
    pub bank_depths: Vec<u64>,
    /// Latest EWMA lease-rate score (0.0 until traffic arrives).
    pub demand_ewma: f64,
    /// Latest effective refill weight (static demand until the EWMA has
    /// signal).
    pub demand_weight: f64,
}

/// A snapshot for reporting.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub completed: u64,
    pub pool_dry_events: u64,
    pub bytes_online: u64,
    /// Requests sitting in the bounded ingress queue at snapshot time.
    pub ingress_queue_depth: u64,
    /// Requests shed with `Busy` by admission control, fleet-wide.
    pub sheds: u64,
    pub online_p50_us: u64,
    pub online_p99_us: u64,
    pub online_mean_us: f64,
    pub queue_mean_us: f64,
    pub total_p50_us: u64,
    pub total_p99_us: u64,
    pub dry_deal_mean_us: f64,
    pub dry_deal_p99_us: u64,
    pub remote_refills: u64,
    pub remote_sessions: u64,
    pub layer_entries: u64,
    pub bytes_offline_wire: u64,
    pub fp_mismatch_drops: u64,
    pub remote_refill_mean_us: f64,
    pub remote_refill_p99_us: u64,
    /// Mean/max requests per dispatched batch (1.0 ⇒ batching never
    /// kicked in — all windows closed with a single arrival).
    pub batch_size_mean: f64,
    pub batch_size_max: u64,
    /// Amortized per-request online latency inside a batch (batch wall
    /// ÷ batch size); compare with `online_p50_us`/`online_p99_us`
    /// (full-batch wall per request) to attribute batching wins.
    pub batch_req_p50_us: u64,
    pub batch_req_p99_us: u64,
    pub batch_req_mean_us: f64,
    /// Latest per-bank staged depth of **one** model (0 = linear
    /// spines, then one entry per ReLU layer): with a single registered
    /// model, that model's gauge (the single-model convenience); with
    /// several, the first published row in fingerprint order — an
    /// arbitrary model, so multi-model readers use [`Snapshot::models`].
    /// Empty until a pool publishes it.
    pub bank_depths: Vec<u64>,
    pub deal_relus: u64,
    /// Offline dealing throughput, ReLUs per second of dealer-slot wall
    /// time (0.0 before any deal is recorded). Scales with
    /// `deal_threads`: an intra-deal fan-out shortens the wall time of
    /// every deal, raising this number.
    pub deal_relus_per_s: f64,
    /// One row per model that has recorded anything, ordered by
    /// fingerprint.
    pub models: Vec<ModelSnapshot>,
    /// One row per registered dealer link, in pool link order (empty for
    /// inline-refill pools).
    pub links: Vec<LinkSnapshot>,
}

fn rate_per_s(count: u64, wall_us: u64) -> f64 {
    if wall_us == 0 {
        0.0
    } else {
        count as f64 * 1e6 / wall_us as f64
    }
}

impl Metrics {
    fn with_model<F: FnOnce(&mut ModelStats)>(&self, model: u64, f: F) {
        let mut map = self.per_model.lock().unwrap();
        f(map.entry(model).or_default());
    }

    /// Record one completed inference of `model`.
    pub fn record(&self, model: u64, queue_us: u64, online_us: u64, bytes: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.bytes_online.fetch_add(bytes, Ordering::Relaxed);
        {
            let mut g = self.inner.lock().unwrap();
            g.queue_us.record_us(queue_us);
            g.online_us.record_us(online_us);
            g.total_us.record_us(queue_us + online_us);
        }
        self.with_model(model, |m| {
            m.completed += 1;
            m.bytes_online += bytes;
            m.online_us.record_us(online_us);
            m.total_us.record_us(queue_us + online_us);
        });
    }

    /// Record one admission-control shed of a request for `model` (the
    /// request was answered `Busy`, never queued).
    pub fn record_shed(&self, model: u64) {
        self.sheds.fetch_add(1, Ordering::Relaxed);
        self.with_model(model, |m| m.sheds += 1);
    }

    /// Record a pool-dry lease of `model`: bumps the counters and feeds
    /// the measured inline-deal latency into its histogram, so pool-dry
    /// tail latency is visible (e.g. in `serve_pi`), not just its
    /// frequency.
    pub fn record_dry_deal(&self, model: u64, deal_us: u64) {
        self.pool_dry_events.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap().dry_deal_us.record_us(deal_us);
        self.with_model(model, |m| m.pool_dry_events += 1);
    }

    /// Record one whole-session remote refill round trip for `model`:
    /// fetch latency, bytes that crossed the wire, and sessions
    /// delivered. Legacy counterpart of [`Self::record_layer_refill`]
    /// for callers driving `RemoteDealer::fetch` (the whole-`Session`
    /// round) directly — the pool's layer-granular refill path no longer
    /// uses it.
    pub fn record_remote_refill(&self, model: u64, fetch_us: u64, bytes: u64, sessions: u64) {
        self.remote_refills.fetch_add(1, Ordering::Relaxed);
        self.remote_sessions.fetch_add(sessions, Ordering::Relaxed);
        self.bytes_offline_wire.fetch_add(bytes, Ordering::Relaxed);
        self.inner.lock().unwrap().remote_refill_us.record_us(fetch_us);
        self.with_model(model, |m| {
            m.remote_refills += 1;
            m.remote_sessions += sessions;
            m.bytes_offline_wire += bytes;
        });
    }

    /// Record one layer-granular refill round trip for `model`:
    /// `entries` per-layer units fetched, of which `spines` were linear
    /// spines (the sessions'-worth counter — one spine per assembled
    /// session).
    pub fn record_layer_refill(
        &self,
        model: u64,
        fetch_us: u64,
        bytes: u64,
        entries: u64,
        spines: u64,
    ) {
        self.remote_refills.fetch_add(1, Ordering::Relaxed);
        self.layer_entries.fetch_add(entries, Ordering::Relaxed);
        self.remote_sessions.fetch_add(spines, Ordering::Relaxed);
        self.bytes_offline_wire.fetch_add(bytes, Ordering::Relaxed);
        self.inner.lock().unwrap().remote_refill_us.record_us(fetch_us);
        self.with_model(model, |m| {
            m.remote_refills += 1;
            m.layer_entries += entries;
            m.remote_sessions += spines;
            m.bytes_offline_wire += bytes;
        });
    }

    /// Record one dispatched batch of `model`: `size` requests executed
    /// as one batched walk (1 for the per-request fallback). Called once
    /// per batch, not per request.
    pub fn record_batch_size(&self, model: u64, size: u64) {
        self.inner.lock().unwrap().batch_size.record_us(size);
        self.with_model(model, |m| m.batch_size.record_us(size));
    }

    /// Record one request's amortized share of its batch's online wall
    /// time (batch wall ÷ batch size). Called once per request.
    pub fn record_batch_req(&self, model: u64, us: u64) {
        self.inner.lock().unwrap().batch_req_us.record_us(us);
        self.with_model(model, |m| m.batch_req_us.record_us(us));
    }

    /// Publish one model shard's per-bank staged depths (gauge
    /// semantics: the latest value wins).
    pub fn set_bank_depths(&self, model: u64, depths: Vec<u64>) {
        self.with_model(model, |m| m.bank_depths = depths);
    }

    /// Publish one model's EWMA lease-rate score and the effective
    /// refill weight derived from it (gauge semantics).
    pub fn set_demand(&self, model: u64, ewma: f64, weight: f64) {
        self.with_model(model, |m| {
            m.demand_ewma = ewma;
            m.demand_weight = weight;
        });
    }

    /// Register the dealer-link rows (called once by the pool's fleet
    /// scheduler at start; replaces any previous registration).
    pub fn register_links(&self, labels: &[String]) {
        let mut rows = self.links.lock().unwrap();
        *rows = labels
            .iter()
            .map(|l| LinkStats { label: l.clone(), ..LinkStats::default() })
            .collect();
    }

    fn with_link<F: FnOnce(&mut LinkStats)>(&self, link: usize, f: F) {
        let mut rows = self.links.lock().unwrap();
        if let Some(row) = rows.get_mut(link) {
            f(row);
        }
    }

    /// Record one completed fetch on link `link`: round-trip latency,
    /// wire bytes, and units staged.
    pub fn record_link_fetch(&self, link: usize, fetch_us: u64, bytes: u64, units: u64) {
        self.with_link(link, |l| {
            l.fetches += 1;
            l.bytes += bytes;
            l.units += units;
            l.fetch_us.record_us(fetch_us);
        });
    }

    /// Record a connect/fetch failure on link `link`.
    pub fn record_link_failure(&self, link: usize) {
        self.with_link(link, |l| l.failures += 1);
    }

    /// Record a successful (re)connect after a failure on link `link`.
    pub fn record_link_reconnect(&self, link: usize) {
        self.with_link(link, |l| l.reconnects += 1);
    }

    /// Record a steal: idle link `thief` took over a claim outstanding
    /// on `victim`.
    pub fn record_link_steal(&self, thief: usize, victim: usize) {
        self.with_link(thief, |l| l.steals += 1);
        self.with_link(victim, |l| l.stolen_from += 1);
    }

    /// Record `units` late units from link `link`, produced after its
    /// claim was stolen and therefore discarded at staging.
    pub fn record_link_late_drop(&self, link: usize, units: u64) {
        self.with_link(link, |l| l.late_drop_units += units);
    }

    /// Record one local offline deal for `model`: `relus` ReLUs' worth
    /// of material produced in `us` microseconds of wall time. Fed by
    /// the pool refill threads and by dry leases; the snapshot's
    /// [`Snapshot::deal_relus_per_s`] is the running aggregate.
    pub fn record_deal(&self, model: u64, relus: u64, us: u64) {
        self.deal_relus.fetch_add(relus, Ordering::Relaxed);
        // Clamp to 1µs so a sub-microsecond deal (tiny test plans) still
        // registers time and the ratio stays finite.
        self.deal_wall_us.fetch_add(us.max(1), Ordering::Relaxed);
        self.with_model(model, |m| {
            m.deal_relus += relus;
            m.deal_wall_us += us.max(1);
        });
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let deal_relus = self.deal_relus.load(Ordering::Relaxed);
        let deal_wall_us = self.deal_wall_us.load(Ordering::Relaxed);
        let models: Vec<ModelSnapshot> = self
            .per_model
            .lock()
            .unwrap()
            .iter()
            .map(|(&fingerprint, m)| ModelSnapshot {
                fingerprint,
                completed: m.completed,
                bytes_online: m.bytes_online,
                pool_dry_events: m.pool_dry_events,
                sheds: m.sheds,
                online_p50_us: m.online_us.percentile_us(50.0),
                online_p99_us: m.online_us.percentile_us(99.0),
                online_mean_us: m.online_us.mean_us(),
                total_p50_us: m.total_us.percentile_us(50.0),
                total_p99_us: m.total_us.percentile_us(99.0),
                deal_relus: m.deal_relus,
                deal_relus_per_s: rate_per_s(m.deal_relus, m.deal_wall_us),
                remote_refills: m.remote_refills,
                remote_sessions: m.remote_sessions,
                layer_entries: m.layer_entries,
                bytes_offline_wire: m.bytes_offline_wire,
                batch_size_mean: m.batch_size.mean_us(),
                batch_req_p99_us: m.batch_req_us.percentile_us(99.0),
                bank_depths: m.bank_depths.clone(),
                demand_ewma: m.demand_ewma,
                demand_weight: m.demand_weight,
            })
            .collect();
        let links: Vec<LinkSnapshot> = self
            .links
            .lock()
            .unwrap()
            .iter()
            .map(|l| LinkSnapshot {
                label: l.label.clone(),
                fetches: l.fetches,
                units: l.units,
                bytes: l.bytes,
                failures: l.failures,
                reconnects: l.reconnects,
                steals: l.steals,
                stolen_from: l.stolen_from,
                late_drop_units: l.late_drop_units,
                fetch_p50_us: l.fetch_us.percentile_us(50.0),
                fetch_p99_us: l.fetch_us.percentile_us(99.0),
                fetch_mean_us: l.fetch_us.mean_us(),
            })
            .collect();
        Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            pool_dry_events: self.pool_dry_events.load(Ordering::Relaxed),
            bytes_online: self.bytes_online.load(Ordering::Relaxed),
            ingress_queue_depth: self.ingress_depth.load(Ordering::Relaxed),
            sheds: self.sheds.load(Ordering::Relaxed),
            online_p50_us: g.online_us.percentile_us(50.0),
            online_p99_us: g.online_us.percentile_us(99.0),
            online_mean_us: g.online_us.mean_us(),
            queue_mean_us: g.queue_us.mean_us(),
            total_p50_us: g.total_us.percentile_us(50.0),
            total_p99_us: g.total_us.percentile_us(99.0),
            dry_deal_mean_us: g.dry_deal_us.mean_us(),
            dry_deal_p99_us: g.dry_deal_us.percentile_us(99.0),
            remote_refills: self.remote_refills.load(Ordering::Relaxed),
            remote_sessions: self.remote_sessions.load(Ordering::Relaxed),
            layer_entries: self.layer_entries.load(Ordering::Relaxed),
            bytes_offline_wire: self.bytes_offline_wire.load(Ordering::Relaxed),
            fp_mismatch_drops: self.fp_mismatch_drops.load(Ordering::Relaxed),
            bank_depths: models
                .iter()
                .map(|m| m.bank_depths.clone())
                .find(|d| !d.is_empty())
                .unwrap_or_default(),
            remote_refill_mean_us: g.remote_refill_us.mean_us(),
            remote_refill_p99_us: g.remote_refill_us.percentile_us(99.0),
            batch_size_mean: g.batch_size.mean_us(),
            batch_size_max: g.batch_size.max_us(),
            batch_req_p50_us: g.batch_req_us.percentile_us(50.0),
            batch_req_p99_us: g.batch_req_us.percentile_us(99.0),
            batch_req_mean_us: g.batch_req_us.mean_us(),
            deal_relus,
            deal_relus_per_s: rate_per_s(deal_relus, deal_wall_us),
            models,
            links,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: u64 = 0xA0DE1;

    #[test]
    fn record_and_snapshot() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record(M, 100, 1000, 64);
        m.record(M, 200, 2000, 64);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.completed, 2);
        assert_eq!(s.bytes_online, 128);
        assert!(s.online_mean_us >= 1000.0);
        assert!(s.total_p99_us >= s.total_p50_us);
        assert_eq!(s.models.len(), 1);
        assert_eq!(s.models[0].fingerprint, M);
        assert_eq!(s.models[0].completed, 2);
        assert!(s.models[0].online_mean_us >= 1000.0);
    }

    #[test]
    fn per_model_rows_are_separated() {
        let m = Metrics::default();
        m.record(1, 10, 100, 8);
        m.record(2, 10, 100, 8);
        m.record(2, 10, 100, 8);
        m.record_dry_deal(2, 5_000);
        let s = m.snapshot();
        assert_eq!(s.completed, 3);
        assert_eq!(s.models.len(), 2);
        assert_eq!(s.models[0].fingerprint, 1);
        assert_eq!(s.models[0].completed, 1);
        assert_eq!(s.models[1].completed, 2);
        assert_eq!(s.models[0].pool_dry_events, 0);
        assert_eq!(s.models[1].pool_dry_events, 1);
    }

    #[test]
    fn remote_refill_recorded() {
        let m = Metrics::default();
        let s0 = m.snapshot();
        assert_eq!(s0.remote_refills, 0);
        assert_eq!(s0.bytes_offline_wire, 0);
        m.record_remote_refill(M, 2_000, 1_000_000, 4);
        m.record_remote_refill(M, 4_000, 500_000, 2);
        let s = m.snapshot();
        assert_eq!(s.remote_refills, 2);
        assert_eq!(s.remote_sessions, 6);
        assert_eq!(s.bytes_offline_wire, 1_500_000);
        assert!((s.remote_refill_mean_us - 3_000.0).abs() < 1e-9);
        assert!(s.remote_refill_p99_us >= 4_000);
        assert_eq!(s.models[0].remote_sessions, 6);
    }

    #[test]
    fn layer_refill_and_bank_depths_recorded() {
        let m = Metrics::default();
        assert!(m.snapshot().bank_depths.is_empty());
        m.record_layer_refill(M, 1_000, 500_000, 3, 1);
        m.record_layer_refill(M, 3_000, 250_000, 2, 0);
        m.set_bank_depths(M, vec![4, 2, 7]);
        let s = m.snapshot();
        assert_eq!(s.remote_refills, 2);
        assert_eq!(s.layer_entries, 5);
        assert_eq!(s.remote_sessions, 1);
        assert_eq!(s.bytes_offline_wire, 750_000);
        assert!((s.remote_refill_mean_us - 2_000.0).abs() < 1e-9);
        assert_eq!(s.bank_depths, vec![4, 2, 7]);
        assert_eq!(s.models[0].bank_depths, vec![4, 2, 7]);
        assert_eq!(s.models[0].layer_entries, 5);
    }

    #[test]
    fn deal_throughput_recorded() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().deal_relus_per_s, 0.0, "no div-by-zero before first deal");
        m.record_deal(M, 500, 250_000);
        m.record_deal(M, 500, 250_000);
        let s = m.snapshot();
        assert_eq!(s.deal_relus, 1000);
        assert!((s.deal_relus_per_s - 2000.0).abs() < 1e-9);
        assert!((s.models[0].deal_relus_per_s - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn batch_histograms_recorded() {
        let m = Metrics::default();
        let s0 = m.snapshot();
        assert_eq!(s0.batch_size_mean, 0.0);
        assert_eq!(s0.batch_req_mean_us, 0.0);
        m.record_batch_size(M, 8);
        m.record_batch_size(M, 4);
        for _ in 0..8 {
            m.record_batch_req(M, 1_000);
        }
        for _ in 0..4 {
            m.record_batch_req(M, 3_000);
        }
        let s = m.snapshot();
        assert!((s.batch_size_mean - 6.0).abs() < 1e-9);
        assert!(s.batch_size_max >= 8);
        let want_mean = (8.0 * 1_000.0 + 4.0 * 3_000.0) / 12.0;
        assert!((s.batch_req_mean_us - want_mean).abs() < 1e-9);
        assert!(s.batch_req_p99_us >= 3_000);
        assert!((s.models[0].batch_size_mean - 6.0).abs() < 1e-9);
        assert!(s.models[0].batch_req_p99_us >= 3_000);
    }

    #[test]
    fn sheds_and_queue_gauge_recorded() {
        let m = Metrics::default();
        m.ingress_depth.fetch_add(3, Ordering::Relaxed);
        m.record_shed(M);
        m.record_shed(M);
        m.record_shed(7);
        let s = m.snapshot();
        assert_eq!(s.ingress_queue_depth, 3);
        assert_eq!(s.sheds, 3);
        let row = s.models.iter().find(|r| r.fingerprint == M).unwrap();
        assert_eq!(row.sheds, 2);
        let other = s.models.iter().find(|r| r.fingerprint == 7).unwrap();
        assert_eq!(other.sheds, 1);
    }

    #[test]
    fn link_rows_and_demand_gauges_recorded() {
        let m = Metrics::default();
        assert!(m.snapshot().links.is_empty(), "no rows before registration");
        m.register_links(&["dealer-a".to_string(), "dealer-b".to_string()]);
        m.record_link_fetch(0, 2_000, 4_096, 8);
        m.record_link_fetch(0, 4_000, 4_096, 8);
        m.record_link_failure(1);
        m.record_link_reconnect(1);
        m.record_link_steal(0, 1);
        m.record_link_late_drop(1, 8);
        // Out-of-range link indices are ignored, not panics.
        m.record_link_fetch(9, 1, 1, 1);
        m.set_demand(M, 12.5, 0.8);
        let s = m.snapshot();
        assert_eq!(s.links.len(), 2);
        let a = &s.links[0];
        assert_eq!(a.label, "dealer-a");
        assert_eq!(a.fetches, 2);
        assert_eq!(a.units, 16);
        assert_eq!(a.bytes, 8_192);
        assert_eq!(a.steals, 1);
        assert!((a.fetch_mean_us - 3_000.0).abs() < 1e-9);
        let b = &s.links[1];
        assert_eq!(b.failures, 1);
        assert_eq!(b.reconnects, 1);
        assert_eq!(b.stolen_from, 1);
        assert_eq!(b.late_drop_units, 8);
        let row = s.models.iter().find(|r| r.fingerprint == M).unwrap();
        assert!((row.demand_ewma - 12.5).abs() < 1e-9);
        assert!((row.demand_weight - 0.8).abs() < 1e-9);
    }

    #[test]
    fn dry_deal_latency_recorded() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().dry_deal_mean_us, 0.0);
        m.record_dry_deal(M, 5_000);
        m.record_dry_deal(M, 15_000);
        let s = m.snapshot();
        assert_eq!(s.pool_dry_events, 2);
        assert!((s.dry_deal_mean_us - 10_000.0).abs() < 1e-9);
        assert!(s.dry_deal_p99_us >= 15_000);
    }
}

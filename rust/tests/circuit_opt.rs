//! Contract tests for the circuit-level material squeeze (hash-consing
//! CSE builder + `Circuit::optimize` + memoized templates):
//!
//! * every builder combinator agrees exhaustively with the naive (seed)
//!   builder at small widths;
//! * every `ReluVariant` circuit (all modes, k ∈ {0, 8, 12}) agrees with
//!   its naive build on randomized encoder-shaped and uniform inputs;
//! * the gate-count regression guard: the optimized AND count never
//!   exceeds the seed count (hard fail), total gates strictly shrink for
//!   every variant, and the baseline ReLU sheds ANDs outright;
//! * leased-session inference logits are bit-identical with the
//!   optimizer on and off (the protocol's RNG schedule never depends on
//!   gate structure — only the garbled material's shape does).

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::circuits::{template, trunc_sign_gc};
use circa::field::Fp;
use circa::gc::build::{u64_to_bits, Bit, Builder};
use circa::gc::circuit::Circuit;
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::{offline_network_mt, run_inference, session_rng, NetworkPlan};
use circa::util::Rng;
use std::sync::{Arc, Mutex, OnceLock};

/// Serializes the tests that read or flip the process-global template
/// state (the raw-templates hook and the cache-content assertions).
fn template_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let m = LOCK.get_or_init(|| Mutex::new(()));
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn all_variants() -> Vec<ReluVariant> {
    let mut v = vec![
        ReluVariant::BaselineRelu,
        ReluVariant::NaiveSign,
        ReluVariant::StochasticSign { mode: FaultMode::PosZero },
        ReluVariant::StochasticSign { mode: FaultMode::NegPass },
    ];
    for k in [0u32, 8, 12] {
        v.push(ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero });
        v.push(ReluVariant::TruncatedSign { k, mode: FaultMode::NegPass });
    }
    v
}

fn exhaustive_agree(cse: &Circuit, naive: &Circuit, n_inputs: usize, what: &str) {
    assert_eq!(cse.n_inputs, naive.n_inputs, "{what}: input arity");
    assert!(cse.validate().is_ok(), "{what}: cse validate");
    assert!(naive.validate().is_ok(), "{what}: naive validate");
    let opt = cse.optimize();
    assert!(opt.validate().is_ok(), "{what}: optimized validate");
    for bits in 0u64..(1 << n_inputs) {
        let inputs = u64_to_bits(bits, n_inputs);
        let want = naive.eval_plain(&inputs);
        assert_eq!(cse.eval_plain(&inputs), want, "{what}: cse inputs={bits:#x}");
        assert_eq!(opt.eval_plain(&inputs), want, "{what}: optimized inputs={bits:#x}");
    }
}

/// Build the same component with the CSE and naive builders and compare
/// exhaustively (raw CSE circuit *and* its optimized form).
fn check_component(what: &str, n_inputs: usize, f: impl Fn(&mut Builder)) {
    let mut cse = Builder::new();
    f(&mut cse);
    let mut naive = Builder::new_naive();
    f(&mut naive);
    exhaustive_agree(&cse.build(), &naive.build(), n_inputs, what);
}

#[test]
fn combinators_agree_with_naive_builder_exhaustively() {
    let w = 3usize;
    check_component("add", 2 * w, |b| {
        let x = b.input_bus(w);
        let y = b.input_bus(w);
        let (s, c) = b.add(&x, &y);
        b.output_bus(&s);
        b.output(c);
    });
    check_component("sub", 2 * w, |b| {
        let x = b.input_bus(w);
        let y = b.input_bus(w);
        let (d, bw) = b.sub(&x, &y);
        b.output_bus(&d);
        b.output(bw);
    });
    check_component("cmp", 2 * w, |b| {
        let x = b.input_bus(w);
        let y = b.input_bus(w);
        let geq = b.geq(&x, &y);
        let gt = b.gt(&x, &y);
        let leq = b.leq(&x, &y);
        b.output(geq);
        b.output(gt);
        b.output(leq);
    });
    check_component("mux_bus", 1 + 2 * w, |b| {
        let s = b.input();
        let x = b.input_bus(w);
        let y = b.input_bus(w);
        let o = b.mux_bus(s, &x, &y);
        b.output_bus(&o);
        // Negated selector too (exercises the arm-swap rewrite).
        let ns = b.not(s);
        let o2 = b.mux_bus(ns, &x, &y);
        b.output_bus(&o2);
    });
    check_component("or_chain", 4, |b| {
        let x = b.input_bus(4);
        let mut acc = x[0];
        for &bit in &x[1..] {
            acc = b.or(acc, bit);
        }
        b.output(acc);
        // Same chain again: should be free under CSE, same value always.
        let mut acc2 = x[0];
        for &bit in &x[1..] {
            acc2 = b.or(acc2, bit);
        }
        b.output(acc2);
    });
    // Composite in the Fig. 2 shape: add a constant, subtract, compare
    // against a constant, MUX the difference — the exact pattern the
    // one-level XOR cancellation targets.
    check_component("const_sub_mux", 2 * w, |b| {
        let x = b.input_bus(w);
        let y = b.input_bus(w);
        let (z, zc) = b.add(&x, &y);
        let mut z_ext = z;
        z_ext.push(zc);
        let p = b.const_bus(0b101, w + 1);
        let (z_minus_p, no_borrow) = b.sub(&z_ext, &p);
        let wrap = b.not(no_borrow);
        let sel = b.mux_bus(wrap, &z_minus_p[..w], &z_ext[..w]);
        b.output_bus(&sel);
        let half = b.const_bus(0b011, w);
        let is_neg = b.geq(&sel, &half);
        let zero = b.const_bus(0, w);
        let relu = b.mux_bus(is_neg, &zero, &sel);
        b.output_bus(&relu);
    });
    // Constant outputs ride through materialize's cached anchors.
    check_component("const_outputs", 2, |b| {
        let x = b.input();
        let y = b.input();
        let t = b.and(x, y);
        b.output(t);
        b.output(Bit::Const(true));
        b.output(Bit::Const(false));
        b.output(Bit::Const(true));
    });
}

/// Random full-width agreement for every variant: naive build vs CSE
/// build vs optimized vs the memoized template.
#[test]
fn variant_circuits_agree_with_naive_build_randomized() {
    let _guard = template_lock();
    let mut rng = Rng::new(0xC1AC);
    for variant in all_variants() {
        let spec = variant.spec();
        let naive = spec.build_circuit_naive();
        let opt = spec.build_circuit();
        let cached = spec.circuit();
        assert_eq!(naive.n_inputs, opt.n_inputs, "{variant:?}: input arity");
        assert_eq!(cached.wires, opt.wires, "{variant:?}: cache content");
        assert_eq!(cached.outputs, opt.outputs, "{variant:?}: cache outputs");
        let n_in = spec.n_inputs();
        for iter in 0..200 {
            // Half encoder-shaped inputs (valid field shares), half
            // uniform bit patterns (the circuits are total functions).
            let inputs: Vec<bool> = if iter % 2 == 0 {
                let xc = circa::field::random_fp(&mut rng);
                let xs = circa::field::random_fp(&mut rng);
                let rv = circa::field::random_fp(&mut rng);
                let rout = circa::field::random_fp(&mut rng);
                let mut bits = spec.client_bits(xc, rv, rout);
                bits.extend(spec.server_bits(xs));
                bits
            } else {
                (0..n_in).map(|_| rng.bool()).collect()
            };
            let want = naive.eval_plain(&inputs);
            assert_eq!(opt.eval_plain(&inputs), want, "{variant:?} iter={iter}");
        }
    }
}

/// Gate-count regression guard. Hard-fails if any variant's optimized
/// AND count regresses past its seed (naive) count, if total gates stop
/// strictly shrinking, or if the truncated formula bound breaks; logs
/// the full per-variant table for review.
#[test]
fn gate_counts_never_regress_past_seed() {
    let mut table = String::from(
        "\nvariant                         AND naive/opt   XOR naive/opt   NOT naive/opt   gates naive/opt\n",
    );
    for variant in all_variants() {
        let spec = variant.spec();
        let naive = spec.build_circuit_naive();
        let opt = spec.build_circuit();
        table.push_str(&format!(
            "{:<30} {:>6}/{:<6} {:>7}/{:<7} {:>7}/{:<7} {:>8}/{:<8}\n",
            format!("{variant:?}"),
            naive.n_and(),
            opt.n_and(),
            naive.n_xor(),
            opt.n_xor(),
            naive.n_not(),
            opt.n_not(),
            naive.n_gates(),
            opt.n_gates(),
        ));
        assert!(
            opt.n_and() <= naive.n_and(),
            "{variant:?}: optimized ANDs {} regressed past seed {}{table}",
            opt.n_and(),
            naive.n_and()
        );
        assert!(
            opt.n_gates() < naive.n_gates(),
            "{variant:?}: optimized gates {} not strictly below seed {}{table}",
            opt.n_gates(),
            naive.n_gates()
        );
        // Builds are deterministic: the dealt material layout is a pure
        // function of the variant.
        let again = spec.build_circuit();
        assert_eq!(again.wires, opt.wires, "{variant:?}: non-deterministic build");
        // The optimizer is a fixpoint on its own output.
        let twice = opt.optimize();
        assert_eq!(twice.wires, opt.wires, "{variant:?}: optimize not idempotent");
        if let ReluVariant::TruncatedSign { k, .. } = variant {
            assert!(
                opt.n_and() <= trunc_sign_gc::expected_ands(k),
                "{variant:?}: ANDs exceed the Eq. 3 formula bound"
            );
        }
    }
    let baseline = ReluVariant::BaselineRelu.spec();
    assert!(
        baseline.build_circuit().n_and() < baseline.build_circuit_naive().n_and(),
        "baseline ReLU must shed AND gates under CSE{table}"
    );
    eprintln!("{table}");
}

/// 6 → 5 → relu → 5 → 4 → relu → 4 → 3 synthetic plan (the
/// `tests/online_batch.rs` shape).
fn plan(variant: ReluVariant, seed: u64) -> NetworkPlan {
    let mut rng = Rng::new(seed);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(5, 6, 20, &mut rng)),
        Arc::new(Matrix::random(4, 5, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    NetworkPlan { linears, variant, rescale_bits: Vec::new() }
}

/// End-to-end: lease sessions and run inference with raw (pre-CSE,
/// unoptimized) templates, then again from the same seeds with the
/// optimized templates. The offline RNG schedule draws per *input wire*
/// and per scalar column — never per gate — so logits must be
/// bit-identical; only the garbled material shrinks.
#[test]
fn leased_session_logits_bit_identical_before_and_after_optimizer() {
    let _guard = template_lock();
    let variants = [
        ReluVariant::BaselineRelu,
        ReluVariant::NaiveSign,
        ReluVariant::StochasticSign { mode: FaultMode::NegPass },
        ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
    ];
    for (vi, variant) in variants.into_iter().enumerate() {
        let p = plan(variant, 77 + vi as u64);
        let input: Vec<Fp> = (0..6).map(|j| Fp::from_i64(900 + 7 * j)).collect();

        let run = |raw: bool| {
            template::set_raw_templates_for_tests(raw);
            let out: Vec<_> = (0..2u64)
                .map(|seq| {
                    let (cn, sn, _) =
                        offline_network_mt(&p, &mut session_rng(0xBEEF + vi as u64, seq), 1);
                    let (logits, _) = run_inference(&cn, &sn, &input);
                    logits
                })
                .collect();
            template::set_raw_templates_for_tests(false);
            out
        };
        let before = run(true);
        let after = run(false);
        assert_eq!(before, after, "{variant:?}: logits changed across the optimizer");
    }
}

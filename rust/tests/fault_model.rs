//! Cross-validation: the closed-form fault model (Thms 3.1/3.2), the
//! functional sampler, and the *actual garbled circuit* must all agree.

use circa::circuits::spec::{bits_fp, FaultMode};
use circa::circuits::stoch_sign_gc;
use circa::field::{random_fp, Fp, PRIME};
use circa::gc::{evaluate, garble};
use circa::simfault;
use circa::ss::SharePair;
use circa::util::Rng;

/// The sampler and the garbled circuit must make the SAME decision on
/// the same share split — not just the same distribution.
#[test]
fn gc_and_sampler_agree_pointwise() {
    let mut rng = Rng::new(1);
    for mode in [FaultMode::PosZero, FaultMode::NegPass] {
        for k in [0u32, 8, 14, 20] {
            let circuit = stoch_sign_gc::build_truncated(k, mode);
            for _ in 0..40 {
                let mag = rng.below(1 << 22) as i64;
                let x = Fp::from_i64(if rng.bool() { mag } else { -mag });
                let t = random_fp(&mut rng);
                let r = random_fp(&mut rng);
                let shares = SharePair::share_with_t(x, t);

                // Through the actual GC.
                let (gc, enc) = garble(&circuit, &mut rng);
                let inputs = stoch_sign_gc::encode_inputs(shares.client, shares.server, r, k);
                let out = gc.decode(&evaluate(&circuit, &gc, &enc.encode_all(&inputs)));
                let v_gc = (bits_fp(&out) + r).to_i64();

                // Through the functional sampler with the same t.
                let want = simfault::sample_sign_with_t(x, t, k, mode) as i64;
                assert_eq!(v_gc, want, "x={} t={} k={k} mode={mode:?}", x.to_i64(), t.raw());
            }
        }
    }
}

/// Aggregate rates through the real GC must match the closed form.
#[test]
fn gc_fault_rates_match_closed_form() {
    let mut rng = Rng::new(2);
    let k = 14u32;
    let mode = FaultMode::PosZero;
    let circuit = stoch_sign_gc::build_truncated(k, mode);
    let x = Fp::from_i64((1 << k) / 2); // expected fault rate 0.5
    let n = 600;
    let mut faults = 0;
    for _ in 0..n {
        let t = random_fp(&mut rng);
        let r = random_fp(&mut rng);
        let shares = SharePair::share_with_t(x, t);
        let (gc, enc) = garble(&circuit, &mut rng);
        let inputs = stoch_sign_gc::encode_inputs(shares.client, shares.server, r, k);
        let out = gc.decode(&evaluate(&circuit, &gc, &enc.encode_all(&inputs)));
        if (bits_fp(&out) + r).to_i64() != 1 {
            faults += 1;
        }
    }
    let rate = faults as f64 / n as f64;
    let want = simfault::fault_prob(x, k, mode);
    assert!((rate - want).abs() < 0.07, "rate {rate} want {want}");
}

/// Theorem 3.1's |x|/p law measured at several magnitudes.
#[test]
fn thm31_scaling_in_magnitude() {
    let mut rng = Rng::new(3);
    for frac in [16u64, 8, 4] {
        let x = Fp::new(PRIME / frac); // positive value of magnitude p/frac
        let n = 20_000;
        let mut faults = 0;
        for _ in 0..n {
            if simfault::sample_sign(x, 0, FaultMode::PosZero, &mut rng) != x.is_nonneg() {
                faults += 1;
            }
        }
        let rate = faults as f64 / n as f64;
        let want = 1.0 / frac as f64;
        assert!((rate - want).abs() < 0.02, "frac={frac}: {rate} vs {want}");
    }
}

/// Thm 3.2 is *conditional* on the stochastic sign being correct; the
/// two fault sources must not interact for moderate x.
#[test]
fn fault_sources_compose() {
    let mut rng = Rng::new(4);
    let k = 10u32;
    // x inside trunc range: trunc term dominates (sign term ~ 2^9/2^31).
    let x = Fp::from_i64(1 << 9);
    let want = simfault::fault_prob(x, k, FaultMode::PosZero);
    assert!((want - 0.5).abs() < 0.01);
    let n = 20_000;
    let mut faults = 0;
    for _ in 0..n {
        if simfault::sample_sign(x, k, FaultMode::PosZero, &mut rng) != x.is_nonneg() {
            faults += 1;
        }
    }
    assert!((faults as f64 / n as f64 - want).abs() < 0.02);
}

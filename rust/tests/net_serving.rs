//! Integration tests for the network serving tier ([`circa::net`]):
//! one reactor thread multiplexing hundreds of loopback connections,
//! bank-depth admission control shedding exactly the dry model, and
//! corrupt-frame resilience. No artifacts required — every test builds
//! small random plans in-process.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{ModelConfig, PiService, ServiceConfig};
use circa::field::{relu_exact, Fp};
use circa::net::{AdmitConfig, Outcome, PiClient, Reactor, ReactorConfig};
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::NetworkPlan;
use circa::util::Rng;
use circa::wire::frame::{crc32, encode_frame, MsgType};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn shared_linears(seed: u64) -> Vec<Arc<dyn LinearOp>> {
    let mut rng = Rng::new(seed);
    vec![
        Arc::new(Matrix::random(5, 6, 10, &mut rng)) as Arc<dyn LinearOp>,
        Arc::new(Matrix::random(3, 5, 10, &mut rng)) as Arc<dyn LinearOp>,
    ]
}

fn oracle(linears: &[Arc<dyn LinearOp>], input: &[Fp]) -> Vec<Fp> {
    let mid: Vec<Fp> = linears[0].apply(input).iter().map(|&v| relu_exact(v)).collect();
    linears[1].apply(&mid)
}

#[test]
fn many_concurrent_connections_bit_identical_to_in_process() {
    // ≥256 concurrent loopback connections through ONE reactor thread,
    // every response bit-identical to the in-process infer of the same
    // input. BaselineRelu is deterministic, so equality is exact.
    const CONNS: usize = 256;
    const DISTINCT: usize = 8;

    let linears = shared_linears(21);
    let plan = Arc::new(NetworkPlan::unscaled(linears.clone(), ReluVariant::BaselineRelu));
    let svc = Arc::new(PiService::start(plan, ServiceConfig {
        workers: 4,
        pool_target: 16,
        pool_dealers: 2,
        max_queue: 2 * CONNS,
        ..Default::default()
    }));
    svc.warmup(8);
    // Admission disabled (low_watermark 0) and queue limit above the
    // burst: all 256 must be served, none shed.
    let cfg = ReactorConfig {
        admit: AdmitConfig {
            low_watermark: 0,
            max_queue: 2 * CONNS,
            ..AdmitConfig::default()
        },
        ..ReactorConfig::default()
    };
    let reactor = Reactor::spawn("127.0.0.1:0", svc.clone(), cfg).unwrap();
    let addr = reactor.local_addr().to_string();

    let inputs: Vec<Vec<Fp>> = (0..DISTINCT as i64)
        .map(|s| (0..6).map(|i| Fp::from_i64(100 * s + 7 * i)).collect())
        .collect();
    let want: Vec<Vec<Fp>> =
        inputs.iter().map(|inp| svc.infer(inp.clone()).unwrap().logits).collect();
    // In-process private inference already matches the plaintext oracle
    // (BaselineRelu is exact); the network path must match both.
    for (inp, w) in inputs.iter().zip(&want) {
        assert_eq!(*w, oracle(&linears, inp));
    }

    let handles: Vec<_> = (0..CONNS)
        .map(|c| {
            let addr = addr.clone();
            let input = inputs[c % DISTINCT].clone();
            let want = want[c % DISTINCT].clone();
            std::thread::spawn(move || {
                let mut client = PiClient::connect(&addr).expect("connect");
                let fp = client.models()[0].fingerprint;
                match client.infer(fp, &input).expect("infer") {
                    Outcome::Logits(l) => {
                        assert_eq!(l.logits, want, "conn {c}: network != in-process");
                        assert_eq!(l.req_id, 0);
                    }
                    Outcome::Busy(b) => {
                        panic!("conn {c} shed with admission disabled: {}", b.reason)
                    }
                }
                let _ = client.bye();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    assert!(
        reactor.stats.accepted.load(Ordering::Relaxed) >= CONNS as u64,
        "reactor accepted fewer than {CONNS} connections"
    );
    assert_eq!(reactor.stats.sheds.load(Ordering::Relaxed), 0);
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.completed, (CONNS + DISTINCT) as u64);
    assert_eq!(snap.ingress_queue_depth, 0, "queue gauge drains to zero");

    reactor.shutdown();
    Arc::try_unwrap(svc).ok().expect("sole service owner").shutdown();
}

#[test]
fn dry_bank_sheds_busy_while_healthy_model_serves() {
    // Two co-hosted models; model B's material bank is drained with
    // refill frozen. B's requests must shed with an explicit Busy (and
    // increment the shed counters); model A serves unaffected on the
    // same connection.
    let linears = shared_linears(23);
    let plan_a = Arc::new(NetworkPlan::unscaled(linears.clone(), ReluVariant::BaselineRelu));
    let plan_b = Arc::new(NetworkPlan::unscaled(
        linears,
        ReluVariant::TruncatedSign { k: 4, mode: FaultMode::PosZero },
    ));
    let svc = Arc::new(
        PiService::start_multi(
            vec![(plan_a, ModelConfig::default()), (plan_b, ModelConfig::default())],
            ServiceConfig { workers: 2, pool_target: 4, pool_dealers: 1, ..Default::default() },
        )
        .unwrap(),
    );
    svc.warmup(2);
    let models = svc.models();
    let (model_a, model_b) = (models[0], models[1]);

    // Freeze refill, then drain B's bank completely.
    svc.pool.stop();
    let mut rng = Rng::new(31);
    while svc.pool.banked_model(model_b) > 0 {
        let _ = svc.pool.lease_model(model_b, &mut rng);
    }
    assert!(svc.pool.banked_model(model_a) > 0, "A must stay healthy for the contrast");

    let cfg = ReactorConfig {
        admit: AdmitConfig {
            low_watermark: 1,
            high_watermark: 2,
            sample_interval: Duration::from_secs(0),
            ..AdmitConfig::default()
        },
        ..ReactorConfig::default()
    };
    let reactor = Reactor::spawn("127.0.0.1:0", svc.clone(), cfg).unwrap();
    let mut client = PiClient::connect(&reactor.local_addr().to_string()).unwrap();
    let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(1500 + i)).collect();

    match client.infer(model_b, &input).unwrap() {
        Outcome::Busy(b) => {
            assert!(b.reason.contains("dry"), "{}", b.reason);
            assert!(b.retry_after_ms > 0);
        }
        Outcome::Logits(_) => panic!("dry model B was served instead of shed"),
    }
    match client.infer(model_a, &input).unwrap() {
        Outcome::Logits(l) => assert_eq!(l.model, model_a),
        Outcome::Busy(b) => panic!("healthy model A shed: {}", b.reason),
    }

    assert!(reactor.stats.sheds.load(Ordering::Relaxed) >= 1);
    let snap = svc.metrics.snapshot();
    assert!(snap.sheds >= 1, "fleet shed counter increments");
    let row_b = snap.models.iter().find(|r| r.fingerprint == model_b).unwrap();
    let row_a = snap.models.iter().find(|r| r.fingerprint == model_a).unwrap();
    assert!(row_b.sheds >= 1, "shed lands on the dry model's row");
    assert_eq!(row_a.sheds, 0, "healthy model unaffected");

    let _ = client.bye();
    reactor.shutdown();
    Arc::try_unwrap(svc).ok().expect("sole service owner").shutdown();
}

/// Raw loopback socket for hand-crafted (malformed) byte streams.
fn raw_conn(addr: &str) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

#[test]
fn malformed_frames_kill_one_connection_not_the_reactor() {
    let linears = shared_linears(29);
    let plan = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));
    let svc = Arc::new(PiService::start(plan, ServiceConfig {
        workers: 2,
        pool_target: 4,
        pool_dealers: 1,
        ..Default::default()
    }));
    svc.warmup(2);
    let reactor =
        Reactor::spawn("127.0.0.1:0", svc.clone(), ReactorConfig::default()).unwrap();
    let addr = reactor.local_addr().to_string();

    // (a) Unknown message type: first byte is no MsgType.
    {
        let mut s = raw_conn(&addr);
        s.write_all(&[0xEE, 1, 0, 0, 0, 42, 0, 0, 0, 0]).unwrap();
        // Server reports a connection-fatal error frame, then closes.
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
    }

    // (b) Truncated frame: a valid header promising more payload than
    // ever arrives, then an abrupt close. Nothing to assert on the wire
    // — the reactor must simply survive the dangling partial frame.
    {
        let mut s = raw_conn(&addr);
        let frame = encode_frame(MsgType::ClientHello, b"cirp-truncated").unwrap();
        s.write_all(&frame[..frame.len() - 6]).unwrap();
    }

    // (c) CRC flip: correct structure, one corrupted payload byte.
    {
        let mut s = raw_conn(&addr);
        let mut frame =
            encode_frame(MsgType::ClientHello, &circa::net::proto::encode_client_hello())
                .unwrap();
        let mid = frame.len() - 6;
        frame[mid] ^= 0x40;
        s.write_all(&frame).unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf); // error frame then EOF
    }

    // (d) Oversized LEN header.
    {
        let mut s = raw_conn(&addr);
        let mut header = vec![MsgType::ClientHello as u8];
        header.extend_from_slice(&u32::MAX.to_le_bytes());
        let crc = crc32(&header);
        header.extend_from_slice(&crc.to_le_bytes());
        s.write_all(&header).unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
    }

    // After all four abuse cases the reactor still serves a well-formed
    // client on a fresh connection.
    let mut client = PiClient::connect(&addr).expect("reactor survived corrupt frames");
    let fp = client.models()[0].fingerprint;
    let input: Vec<Fp> = (0..6).map(|i| Fp::from_i64(400 + i)).collect();
    match client.infer(fp, &input).unwrap() {
        Outcome::Logits(l) => assert_eq!(l.logits.len(), 3),
        Outcome::Busy(b) => panic!("unexpected shed: {}", b.reason),
    }
    assert!(
        reactor.stats.proto_errors.load(Ordering::Relaxed) >= 3,
        "unknown-type, CRC-flip, and oversized-LEN all count as protocol errors"
    );

    let _ = client.bye();
    reactor.shutdown();
    Arc::try_unwrap(svc).ok().expect("sole service owner").shutdown();
}

#[test]
fn connection_cap_rejects_with_busy_then_recovers() {
    let linears = shared_linears(37);
    let plan = Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu));
    let svc = Arc::new(PiService::start(plan, ServiceConfig {
        workers: 1,
        pool_target: 2,
        pool_dealers: 1,
        ..Default::default()
    }));
    let cfg = ReactorConfig { max_connections: 4, ..ReactorConfig::default() };
    let reactor = Reactor::spawn("127.0.0.1:0", svc.clone(), cfg).unwrap();
    let addr = reactor.local_addr().to_string();

    // Fill the cap with held connections.
    let mut held: Vec<PiClient> =
        (0..4).map(|_| PiClient::connect(&addr).expect("under cap")).collect();

    // The fifth is refused with an explicit Busy at the handshake.
    let over = PiClient::connect(&addr);
    let err = over.err().expect("over-cap connect must fail").to_string();
    assert!(err.contains("busy") || err.contains("capacity"), "{err}");
    assert!(reactor.stats.rejected_over_cap.load(Ordering::Relaxed) >= 1);

    // Release one slot; the reactor reaps the EOF and admits again.
    drop(held.pop());
    let mut admitted = None;
    for _ in 0..100 {
        match PiClient::connect(&addr) {
            Ok(c) => {
                admitted = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    assert!(admitted.is_some(), "freed capacity never readmitted a client");
    drop(held);

    reactor.shutdown();
    Arc::try_unwrap(svc).ok().expect("sole service owner").shutdown();
}

//! Shared helpers for the artifact-dependent integration test crates.

use circa::runtime::ArtifactDir;

/// `Some(dir)` when the AOT artifacts exist, `None` (and a skip note on
/// stderr) otherwise — keeps `cargo test -q` green on machines that
/// never ran `make artifacts`.
pub fn artifacts_or_skip(test: &str) -> Option<ArtifactDir> {
    match ArtifactDir::discover() {
        Ok(dir) => Some(dir),
        Err(e) => {
            eprintln!("skipping {test}: {e}");
            None
        }
    }
}

//! Cross-module integration: serving coordinator over the real demo CNN,
//! failure injection, and whole-stack invariants. Requires
//! `make artifacts`; every test self-skips (with a note on stderr) when
//! the artifacts are absent so `cargo test -q` stays green on machines
//! that never built them.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{PiService, ServiceConfig};
use circa::nn::weights::{load_dataset, load_weights};
use circa::protocol::server::NetworkPlan;
use circa::runtime::ArtifactDir;
use std::sync::Arc;

mod common;
use common::artifacts_or_skip;

fn demo_plan(dir: &ArtifactDir, variant: ReluVariant) -> Arc<NetworkPlan> {
    let net = load_weights(&dir.path("weights.bin")).unwrap();
    Arc::new(NetworkPlan { linears: net.linears(), variant, rescale_bits: net.rescale_bits() })
}

#[test]
fn service_serves_demo_cnn_with_circa() {
    let Some(dir) = artifacts_or_skip("service_serves_demo_cnn_with_circa") else {
        return;
    };
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let plan = demo_plan(&dir, ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero });
    let svc = PiService::start(
        plan,
        ServiceConfig { workers: 2, pool_target: 6, pool_dealers: 2, ..Default::default() },
    );
    svc.warmup(2);

    let n = 8;
    let mut correct = 0;
    let rxs: Vec<_> =
        (0..n).map(|i| (i, svc.submit(ds.image(i).to_vec()).expect("submit"))).collect();
    for (i, rx) in rxs {
        let resp = rx.recv().unwrap();
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| v.to_i64())
            .map(|(c, _)| c as u32)
            .unwrap();
        if pred == ds.labels[i] {
            correct += 1;
        }
        assert!(resp.online_us > 0);
        assert!(resp.bytes > 0);
    }
    // Demo CNN is ~95% accurate; 8 draws at ≥5/8 is a very safe bar.
    assert!(correct >= 5, "only {correct}/8 correct through the private path");
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.completed, n as u64);
    assert!(snap.online_p50_us > 0);
    svc.shutdown();
}

#[test]
fn service_survives_dry_pool_bursts() {
    // Pool target 1 with a burst of requests: most leases go dry and are
    // dealt inline; every request must still complete correctly.
    let Some(dir) = artifacts_or_skip("service_survives_dry_pool_bursts") else {
        return;
    };
    let plan = demo_plan(&dir, ReluVariant::TruncatedSign { k: 10, mode: FaultMode::PosZero });
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let svc = PiService::start(
        plan,
        ServiceConfig { workers: 3, pool_target: 1, pool_dealers: 1, ..Default::default() },
    );
    let rxs: Vec<_> =
        (0..6).map(|i| svc.submit(ds.image(i).to_vec()).expect("submit")).collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits.len(), 10);
    }
    assert_eq!(svc.metrics.snapshot().completed, 6);
    svc.shutdown();
}

#[test]
#[cfg(feature = "pjrt")]
fn artifact_and_protocol_accuracies_are_consistent() {
    // The PJRT path (exact mode) and the protocol path (baseline GC)
    // compute the same quantized network: spot-check one image end to
    // end through both stacks.
    use circa::protocol::server::{offline_network, run_inference};
    use circa::runtime::model_exec::MODE_EXACT;
    use circa::runtime::CnnExecutable;
    use circa::util::Rng;

    let Some(dir) = artifacts_or_skip("artifact_and_protocol_accuracies_are_consistent") else {
        return;
    };
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let client = xla::PjRtClient::cpu().unwrap();
    let exe = CnnExecutable::load_cnn(&client, &dir).unwrap();
    let b = exe.batch;

    let images: Vec<i32> =
        ds.images[..b * ds.dim].iter().map(|f| f.to_i64() as i32).collect();
    let z1 = vec![0i32; b * 512];
    let z2 = vec![0i32; b * 256];
    let out = exe.run(&images, &z1, &z2, 0, MODE_EXACT).unwrap();

    let plan = demo_plan(&dir, ReluVariant::BaselineRelu);
    let mut rng = Rng::new(9);
    let (cn, sn, _) = offline_network(&plan, &mut rng);
    let (logits, _) = run_inference(&cn, &sn, ds.image(0));

    // PJRT argmax == protocol argmax for image 0 (logits may differ by
    // SecureML rescale noise on the protocol side).
    let pjrt_argmax = out.argmax(0);
    let proto_argmax = logits
        .iter()
        .enumerate()
        .max_by_key(|(_, v)| v.to_i64())
        .map(|(i, _)| i)
        .unwrap();
    assert_eq!(pjrt_argmax, proto_argmax);
}

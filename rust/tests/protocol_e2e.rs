//! End-to-end protocol tests on the *trained* demo CNN (requires
//! `make artifacts`): full 2-party private inference through real conv
//! layers, garbled circuits, Beaver triples, and SecureML rescaling —
//! checked against the plaintext quantized forward pass. Every test
//! self-skips (with a note on stderr) when the artifacts are absent so
//! `cargo test -q` stays green on machines that never built them.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::nn::weights::{accuracy, load_dataset, load_weights};
use circa::protocol::server::{offline_network, run_inference, NetworkPlan};
use circa::runtime::ArtifactDir;
use circa::util::Rng;

mod common;
use common::artifacts_or_skip;

fn plan(
    dir: &ArtifactDir,
    variant: ReluVariant,
) -> (NetworkPlan, circa::nn::weights::LoadedNet) {
    let net = load_weights(&dir.path("weights.bin")).unwrap();
    (
        NetworkPlan { linears: net.linears(), variant, rescale_bits: net.rescale_bits() },
        net,
    )
}

/// Private inference with Circa (k=12) must match the plaintext
/// quantized forward at the argmax level and be within
/// SecureML-truncation noise at the logit level.
#[test]
fn private_cnn_matches_plaintext_argmax() {
    let Some(dir) = artifacts_or_skip("private_cnn_matches_plaintext_argmax") else {
        return;
    };
    let variant = ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero };
    let (p, net) = plan(&dir, variant);
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let mut rng = Rng::new(1);

    let n = 6;
    let mut priv_logits = Vec::new();
    let mut plain_logits = Vec::new();
    for i in 0..n {
        let (cn, sn, _) = offline_network(&p, &mut rng);
        let (logits, stats) = run_inference(&cn, &sn, ds.image(i));
        assert!(stats.bytes_to_client > 0);
        priv_logits.push(logits);
        plain_logits.push(net.forward_exact(ds.image(i)));
    }
    let labels = &ds.labels[..n];
    let acc_priv = accuracy(&priv_logits, labels);
    let acc_plain = accuracy(&plain_logits, labels);
    assert!(
        (acc_priv - acc_plain).abs() <= 1.0 / n as f64 + 1e-9,
        "private {acc_priv} vs plaintext {acc_plain}"
    );
    // Logits agree within the two legitimate noise sources: (a) ±1-ULP
    // SecureML rescale noise amplified by downstream weights, (b) the
    // k=12 truncation faults themselves (plaintext keeps activations
    // < 2^12 that Circa zeroes). Both are small against typical logit
    // gaps (~10^5 at the 2^15 logit scale).
    for (pv, pl) in priv_logits.iter().zip(&plain_logits) {
        for (a, b) in pv.iter().zip(pl) {
            let diff = (a.to_i64() - b.to_i64()).abs();
            assert!(diff < 50_000, "logit diff {diff} ({} vs {})", a.to_i64(), b.to_i64());
        }
    }
}

/// The baseline GC variant on the same network must also reconstruct
/// correctly (exact ReLU; only rescale noise).
#[test]
fn private_cnn_baseline_variant() {
    let Some(dir) = artifacts_or_skip("private_cnn_baseline_variant") else {
        return;
    };
    let (p, net) = plan(&dir, ReluVariant::BaselineRelu);
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let mut rng = Rng::new(2);
    let (cn, sn, _) = offline_network(&p, &mut rng);
    let (logits, _) = run_inference(&cn, &sn, ds.image(0));
    let want = net.forward_exact(ds.image(0));
    for (a, b) in logits.iter().zip(&want) {
        // Baseline = exact ReLU, so only rescale noise remains.
        assert!((a.to_i64() - b.to_i64()).abs() < 50_000);
    }
}

/// NegPass at a destructive k on the real network: small negatives leak
/// through — crash-freedom and mode-flag plumbing test.
#[test]
fn negpass_variant_runs() {
    let Some(dir) = artifacts_or_skip("negpass_variant_runs") else {
        return;
    };
    let (p, _) = plan(&dir, ReluVariant::TruncatedSign { k: 14, mode: FaultMode::NegPass });
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let mut rng = Rng::new(3);
    let (cn, sn, _) = offline_network(&p, &mut rng);
    let (logits, _) = run_inference(&cn, &sn, ds.image(0));
    assert_eq!(logits.len(), 10);
}

/// Circa's offline material must be substantially smaller than the
/// baseline's for the same network (the storage claim at network scale).
#[test]
fn offline_storage_shrinks() {
    let Some(dir) = artifacts_or_skip("offline_storage_shrinks") else {
        return;
    };
    let (pb, _) = plan(&dir, ReluVariant::BaselineRelu);
    let (pc, _) = plan(&dir, ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero });
    let mut rng = Rng::new(4);
    let (_, _, bytes_b) = offline_network(&pb, &mut rng);
    let (_, _, bytes_c) = offline_network(&pc, &mut rng);
    assert!(
        (bytes_c as f64) < 0.6 * bytes_b as f64,
        "circa {bytes_c} vs baseline {bytes_b}"
    );
}

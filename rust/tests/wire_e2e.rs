//! Acceptance tests for the wire subsystem: the encode→decode roundtrip
//! of every layer batch is bit-identical (all variants, k ∈ {0, 8, 12}),
//! an end-to-end inference using only wire-delivered material produces
//! shares identical to the inline-deal path, and the dealer↔coordinator
//! link works over both the in-memory channel and a real TCP socket on
//! localhost. Corrupt payloads must surface errors, never panics.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{MaterialPool, Metrics, RefillSource};
use circa::field::{random_fp, Fp};
use circa::protocol::client::ClientLayer;
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::offline::offline_relu_layer;
use circa::protocol::server::{
    offline_network, offline_network_mt, run_inference, session_rng, NetworkPlan, ServerLayer,
};
use circa::util::bytes::{Reader, Writer};
use circa::util::Rng;
use circa::wire::codec;
use circa::wire::dealer::{deal_session, spawn_mem_dealer, spawn_tcp_dealer, RemoteDealer};
use circa::wire::frame::{FRAME_CRC_BYTES, FRAME_HEADER_BYTES};
use std::sync::Arc;

fn all_variants() -> Vec<ReluVariant> {
    let mut v = vec![
        ReluVariant::BaselineRelu,
        ReluVariant::NaiveSign,
        ReluVariant::StochasticSign { mode: FaultMode::PosZero },
        ReluVariant::StochasticSign { mode: FaultMode::NegPass },
    ];
    for k in [0u32, 8, 12] {
        v.push(ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero });
        v.push(ReluVariant::TruncatedSign { k, mode: FaultMode::NegPass });
    }
    v
}

fn tiny_plan(variant: ReluVariant, seed: u64) -> Arc<NetworkPlan> {
    let mut rng = Rng::new(seed);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(5, 6, 20, &mut rng)),
        Arc::new(Matrix::random(4, 5, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(linears, variant))
}

#[test]
fn layer_roundtrip_bit_identical_all_variants() {
    for (i, variant) in all_variants().into_iter().enumerate() {
        let mut rng = Rng::new(900 + i as u64);
        let xc: Vec<Fp> = (0..12).map(|_| random_fp(&mut rng)).collect();
        let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng);

        let mut w = Writer::new();
        codec::put_client_relu(&mut w, &cm);
        codec::put_server_relu(&mut w, &sm);
        let mut r = Reader::new(&w.buf);
        let c2 = codec::get_client_relu(&mut r).unwrap();
        let s2 = codec::get_server_relu(&mut r).unwrap();
        assert_eq!(r.remaining(), 0, "{variant:?}: trailing bytes");

        // Client side, every buffer bit-identical.
        assert_eq!(c2.spec, cm.spec, "{variant:?}");
        assert_eq!(c2.gc.tables(), cm.gc.tables(), "{variant:?}: tables");
        assert_eq!(c2.gc.output_decode(), cm.gc.output_decode(), "{variant:?}: decode");
        assert_eq!(c2.client_labels, cm.client_labels, "{variant:?}: client labels");
        assert_eq!(c2.r_v, cm.r_v, "{variant:?}: r_v");
        assert_eq!(c2.r_out, cm.r_out, "{variant:?}: r_out");
        assert_eq!(c2.offline_bytes, cm.offline_bytes, "{variant:?}: offline bytes");
        assert_eq!(c2.triples.len(), cm.triples.len(), "{variant:?}: triple count");
        for (a, b) in c2.triples.iter().zip(&cm.triples) {
            assert_eq!((a.a, a.b, a.ab), (b.a, b.b, b.ab), "{variant:?}: triple");
        }

        // Server side.
        assert_eq!(s2.encodings.stride(), sm.encodings.stride(), "{variant:?}: stride");
        assert_eq!(s2.encodings.label0(), sm.encodings.label0(), "{variant:?}: label0");
        assert_eq!(
            s2.encodings.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
            sm.encodings.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
            "{variant:?}: deltas"
        );
        assert_eq!(s2.output_decode, sm.output_decode, "{variant:?}: server decode");
        for (a, b) in s2.triples.iter().zip(&sm.triples) {
            assert_eq!((a.a, a.b, a.ab), (b.a, b.b, b.ab), "{variant:?}: server triple");
        }
    }
}

#[test]
fn session_roundtrip_inference_identical() {
    // A whole dealt session survives the codec: the decoded session must
    // produce the *identical* transcript (logits and byte counts), not
    // merely a correct one.
    for (i, variant) in [
        ReluVariant::BaselineRelu,
        ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero },
        ReluVariant::TruncatedSign { k: 12, mode: FaultMode::NegPass },
    ]
    .into_iter()
    .enumerate()
    {
        let plan = tiny_plan(variant, 40 + i as u64);
        let mut rng = Rng::new(50 + i as u64);
        let (client, server, offline_bytes) = offline_network(&plan, &mut rng);
        let session =
            circa::coordinator::pool::Session { client, server, offline_bytes };

        let bytes = codec::encode_session(&session);
        let decoded = codec::decode_session(&bytes, &plan).unwrap();
        assert_eq!(decoded.offline_bytes, session.offline_bytes);

        let input: Vec<Fp> = (0..6).map(|j| Fp::from_i64(1500 + 31 * j)).collect();
        let (logits_a, stats_a) = run_inference(&session.client, &session.server, &input);
        let (logits_b, stats_b) = run_inference(&decoded.client, &decoded.server, &input);
        assert_eq!(logits_a, logits_b, "{variant:?}: logits");
        assert_eq!(stats_a.bytes_to_client, stats_b.bytes_to_client, "{variant:?}");
        assert_eq!(stats_a.bytes_to_server, stats_b.bytes_to_server, "{variant:?}");
    }
}

#[test]
fn mem_channel_dealer_matches_inline_deal_end_to_end() {
    // The acceptance property: an inference run entirely on material that
    // crossed the wire produces shares identical to the inline-deal path
    // (same dealer RNG stream on both sides).
    let plan = tiny_plan(ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero }, 7);
    let dealer_seed = 0xD00D;
    let registry = circa::coordinator::ModelRegistry::single(plan.clone(), dealer_seed);
    let fp = registry.fingerprints()[0];
    // Dealer fans each session over 4 threads; the column schedule keeps
    // its output identical to the 1-thread inline deal below.
    let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), dealer_seed, 4);
    let mut dealer = RemoteDealer::connect(chan, registry).unwrap();
    let sessions = dealer.fetch(fp, 3).unwrap();
    assert!(dealer.bytes_received() > 0);
    dealer.close();
    dealer_thread.join().unwrap();

    let mut inline_rng = Rng::new(dealer_seed);
    for (i, session) in sessions.into_iter().enumerate() {
        let inline = deal_session(&plan, &mut inline_rng);
        assert_eq!(session.offline_bytes, inline.offline_bytes, "session {i}");
        let input: Vec<Fp> = (0..6).map(|j| Fp::from_i64(2000 + 17 * (i as i64) + j)).collect();
        let (wire_logits, _) = run_inference(&session.client, &session.server, &input);
        let (inline_logits, _) = run_inference(&inline.client, &inline.server, &input);
        assert_eq!(wire_logits, inline_logits, "session {i}: wire vs inline shares");
    }
}

#[test]
fn tcp_dealer_refills_pool_and_serves() {
    // Real localhost socket: a TCP dealer feeds a MaterialPool via
    // RefillSource::Remote; leased sessions serve correct inferences and
    // the refill metrics fill in.
    let plan = tiny_plan(ReluVariant::BaselineRelu, 11);
    let handle = spawn_tcp_dealer("127.0.0.1:0", plan.clone(), 0xFEED, 2).expect("bind dealer");
    let addr = handle.addr().to_string();

    let metrics = Arc::new(Metrics::default());
    let registry = circa::coordinator::ModelRegistry::single(plan.clone(), 0);
    let reg_c = registry.clone();
    let connect: Arc<dyn Fn() -> circa::util::error::Result<RemoteDealer> + Send + Sync> =
        Arc::new(move || RemoteDealer::connect_tcp(&addr, reg_c.clone()));
    let pool = MaterialPool::start_with_source(
        plan.clone(),
        4,
        2,
        3,
        RefillSource::remote_single(connect, 2),
        Some(metrics.clone()),
        1,
    );
    pool.wait_ready(4);

    // Exact-ReLU oracle (baseline variant is exact).
    let input: Vec<Fp> = (0..6).map(|j| Fp::from_i64(1200 + 7 * j)).collect();
    let mut y = input.clone();
    for (i, op) in plan.linears.iter().enumerate() {
        y = op.apply(&y);
        if i + 1 < plan.linears.len() {
            y = y.iter().map(|&v| circa::field::relu_exact(v)).collect();
        }
    }

    let mut rng = Rng::new(5);
    for _ in 0..3 {
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry, "bank must be fed by the TCP dealer");
        let (logits, _) = run_inference(&lease.session.client, &lease.session.server, &input);
        assert_eq!(logits, y, "wire-fed session must serve exact baseline ReLU");
    }

    let snap = metrics.snapshot();
    assert!(snap.remote_refills >= 1);
    assert!(snap.remote_sessions >= 4);
    assert!(snap.bytes_offline_wire > 0);
    pool.shutdown();
    handle.stop();
}

#[test]
fn tcp_streaming_layer_refill_matches_inline_whole_session_deals() {
    // The sharding acceptance property over a real socket: a session
    // assembled from per-layer banks, streamed over TCP by the
    // RequestLayers round, produces inference transcripts bit-identical
    // to an inline whole-session deal from the same session RNG.
    let plan = tiny_plan(ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero }, 21);
    let dealer_seed = 0xFADE;
    let handle = spawn_tcp_dealer("127.0.0.1:0", plan.clone(), dealer_seed, 2).expect("bind");
    let addr = handle.addr().to_string();

    let metrics = Arc::new(Metrics::default());
    let registry = circa::coordinator::ModelRegistry::single(plan.clone(), 0);
    let reg_c = registry.clone();
    let connect: Arc<dyn Fn() -> circa::util::error::Result<RemoteDealer> + Send + Sync> =
        Arc::new(move || RemoteDealer::connect_tcp(&addr, reg_c.clone()));
    let pool = MaterialPool::start_with_source(
        plan.clone(),
        3,
        2,
        9,
        RefillSource::remote_single(connect, 2),
        Some(metrics.clone()),
        1,
    );
    pool.wait_ready(3);

    let input: Vec<Fp> = (0..6).map(|j| Fp::from_i64(1400 + 5 * j)).collect();
    let mut rng = Rng::new(6);
    for seq in 0..3u64 {
        let lease = pool.lease(&mut rng);
        assert!(!lease.was_dry, "bank must be fed by the streaming dealer");
        let (client, server, offline_bytes) =
            offline_network_mt(&plan, &mut session_rng(dealer_seed, seq), 1);
        assert_eq!(lease.session.offline_bytes, offline_bytes, "seq {seq}: bytes");
        let (wire_logits, wire_stats) =
            run_inference(&lease.session.client, &lease.session.server, &input);
        let (inline_logits, inline_stats) = run_inference(&client, &server, &input);
        assert_eq!(wire_logits, inline_logits, "seq {seq}: transcript logits");
        assert_eq!(wire_stats.bytes_to_client, inline_stats.bytes_to_client, "seq {seq}");
        assert_eq!(wire_stats.bytes_to_server, inline_stats.bytes_to_server, "seq {seq}");
    }

    // tiny_plan has 2 ReLU layers: each session's worth is 1 spine + 2
    // layer batches.
    let snap = metrics.snapshot();
    assert!(snap.remote_refills >= 1);
    assert!(snap.remote_sessions >= 3, "spines: {}", snap.remote_sessions);
    assert!(snap.layer_entries >= 9, "units: {}", snap.layer_entries);
    assert!(snap.bytes_offline_wire > 0);
    assert_eq!(snap.bank_depths.len(), 3, "spine bank + 2 relu banks");
    pool.shutdown();
    handle.stop();
}

#[test]
fn streamed_frames_bounded_by_largest_layer_not_session() {
    // The wire-size acceptance bound: for a multi-layer plan, the
    // largest frame of the layer-granular round is one layer batch —
    // strictly smaller than the whole-session frame the legacy round
    // would ship.
    let plan = tiny_plan(ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero }, 23);
    let dealer_seed = 0xB0B;
    let registry = circa::coordinator::ModelRegistry::single(plan.clone(), dealer_seed);
    let fp = registry.fingerprints()[0];
    let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), dealer_seed, 1);
    let mut dealer = RemoteDealer::connect(chan, registry).unwrap();
    let spines = dealer.fetch_spines(fp, &[0]).unwrap();
    assert_eq!(spines.len(), 1);
    for li in 0..plan.n_relu_layers() {
        let layers = dealer.fetch_layers(fp, li, &[0]).unwrap();
        assert_eq!(layers.len(), 1);
    }
    let max_frame = dealer.max_frame_received();
    dealer.close();
    let _ = dealer_thread.join();

    // Re-derive the same session inline to size the comparison frames.
    let (client, server, offline_bytes) =
        offline_network_mt(&plan, &mut session_rng(dealer_seed, 0), 1);
    let session = circa::coordinator::pool::Session { client, server, offline_bytes };
    let session_frame =
        (codec::encode_session(&session).len() + FRAME_HEADER_BYTES + FRAME_CRC_BYTES) as u64;

    // Largest single unit frame for this session: a layer batch or the
    // spine (the spine carries no GC material, so it only matters for
    // degenerate wide-linear/narrow-ReLU shapes — not this plan, where
    // the assertion below confirms a layer batch dominates).
    let mut largest_layer_frame = 0u64;
    let relu_c: Vec<_> = session
        .client
        .layers
        .iter()
        .filter_map(|l| match l {
            ClientLayer::Relu(m) => Some(m.as_ref()),
            ClientLayer::Linear { .. } => None,
        })
        .collect();
    let relu_s: Vec<_> = session
        .server
        .layers
        .iter()
        .filter_map(|l| match l {
            ServerLayer::Relu { mat, .. } => Some(mat.as_ref()),
            ServerLayer::Linear { .. } => None,
        })
        .collect();
    for (li, (cm, sm)) in relu_c.iter().zip(&relu_s).enumerate() {
        let mut w = Writer::new();
        codec::put_layer_batch(&mut w, fp, li as u32, 0, cm, sm);
        let frame = (w.buf.len() + FRAME_HEADER_BYTES + FRAME_CRC_BYTES) as u64;
        largest_layer_frame = largest_layer_frame.max(frame);
    }
    {
        let spine = circa::protocol::server::deal_spine(&plan, &mut session_rng(dealer_seed, 0));
        let mut w = Writer::new();
        codec::put_spine(&mut w, fp, 0, &spine);
        let frame = (w.buf.len() + FRAME_HEADER_BYTES + FRAME_CRC_BYTES) as u64;
        largest_layer_frame = largest_layer_frame.max(frame);
    }

    assert!(
        max_frame <= largest_layer_frame,
        "largest streamed frame {max_frame} exceeds the largest layer batch \
         {largest_layer_frame}"
    );
    assert!(
        max_frame < session_frame,
        "largest streamed frame {max_frame} not smaller than the whole-session frame \
         {session_frame}"
    );
}

#[test]
fn tcp_handshake_rejects_wrong_plan() {
    let plan = tiny_plan(ReluVariant::BaselineRelu, 11);
    let other = tiny_plan(ReluVariant::NaiveSign, 11);
    let handle = spawn_tcp_dealer("127.0.0.1:0", plan, 1, 1).expect("bind dealer");
    let addr = handle.addr().to_string();
    let err = RemoteDealer::connect_tcp(
        &addr,
        circa::coordinator::ModelRegistry::single(other, 1),
    )
    .unwrap_err();
    assert!(err.to_string().contains("rejected"), "{err}");
    handle.stop();
}

#[test]
fn corrupt_session_payload_errors_never_panics() {
    let plan = tiny_plan(ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero }, 13);
    let mut rng = Rng::new(17);
    let session = deal_session(&plan, &mut rng);
    let valid = codec::encode_session(&session);

    // Truncation at every sampled prefix must error.
    for cut in (0..valid.len()).step_by(97) {
        assert!(codec::decode_session(&valid[..cut], &plan).is_err(), "cut={cut}");
    }
    // Trailing garbage must error.
    let mut padded = valid.clone();
    padded.extend_from_slice(&[0u8; 3]);
    assert!(codec::decode_session(&padded, &plan).is_err());

    // Byte flips anywhere must decode to Ok or Err — never panic. Flips
    // inside label payloads legitimately decode Ok (labels are opaque
    // randomness); structural damage must be caught.
    let mut flips = 0;
    let mut rejected = 0;
    for pos in (0..valid.len()).step_by(41) {
        let mut mutated = valid.clone();
        mutated[pos] ^= 0x5A;
        flips += 1;
        if codec::decode_session(&mutated, &plan).is_err() {
            rejected += 1;
        }
    }
    // The header region (layer counts, tags, lengths) must reject; label
    // regions may not. Just require that *some* structural damage was
    // caught and nothing panicked.
    assert!(rejected >= 1, "no corruption detected across {flips} flips");

    // Decoding against the wrong plan must also error.
    let other = tiny_plan(ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero }, 14);
    // Same dims, same variant — decode succeeds structurally...
    assert!(codec::decode_session(&valid, &other).is_ok());
    // ...but a different-shaped plan is rejected.
    let shaped = tiny_plan(ReluVariant::BaselineRelu, 13);
    assert!(codec::decode_session(&valid, &shaped).is_err());
}

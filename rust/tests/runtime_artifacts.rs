//! Integration: the PJRT runtime executing the AOT artifacts, checked
//! against the Rust-side oracles. Requires the `pjrt` cargo feature (the
//! `xla` crate) and `make artifacts`; the whole file compiles away
//! otherwise so `cargo test -q` passes on machines without either.

#![cfg(feature = "pjrt")]

use circa::circuits::spec::FaultMode;
use circa::field::{Fp, PRIME};
use circa::nn::weights::{accuracy, load_dataset, load_weights};
use circa::runtime::model_exec::{MODE_EXACT, MODE_NEGPASS, MODE_POSZERO};
use circa::runtime::{ArtifactDir, CnnExecutable, StochReluExecutable};
use circa::simfault;
use circa::util::Rng;

fn client() -> xla::PjRtClient {
    xla::PjRtClient::cpu().expect("PJRT CPU client")
}

#[test]
fn stoch_relu_kernel_matches_rust_fault_model() {
    let dir = ArtifactDir::discover().expect("artifacts built");
    let c = client();
    let exe = StochReluExecutable::load(&c, &dir).unwrap();
    let mut rng = Rng::new(1);
    let n = exe.n;
    // Mixed-magnitude signed activations.
    let x: Vec<i32> = (0..n)
        .map(|i| {
            let mag = rng.below(1 << (4 + (i % 24))) as i64;
            (if rng.bool() { mag } else { -mag }) as i32
        })
        .collect();
    let t: Vec<i32> = (0..n).map(|_| rng.below(PRIME) as i32).collect();

    for (k, mode, fm) in [
        (0, MODE_POSZERO, FaultMode::PosZero),
        (12, MODE_POSZERO, FaultMode::PosZero),
        (18, MODE_NEGPASS, FaultMode::NegPass),
    ] {
        let (y, f) = exe.run(&x, &t, k, mode).unwrap();
        for i in 0..n {
            let xi = Fp::from_i64(x[i] as i64);
            let ti = Fp::new(t[i] as u64);
            let want_sign = simfault::sample_sign_with_t(xi, ti, k as u32, fm);
            let want_y = if want_sign { x[i] } else { 0 };
            assert_eq!(y[i], want_y, "i={i} k={k} mode={mode}");
            let want_fault = (want_sign != (x[i] >= 0)) as i32;
            assert_eq!(f[i], want_fault, "fault i={i} k={k}");
        }
    }
}

#[test]
fn exact_mode_kernel_is_relu() {
    let dir = ArtifactDir::discover().expect("artifacts built");
    let c = client();
    let exe = StochReluExecutable::load(&c, &dir).unwrap();
    let mut rng = Rng::new(2);
    let x: Vec<i32> = (0..exe.n).map(|_| rng.below(2_000_001) as i32 - 1_000_000).collect();
    let t: Vec<i32> = (0..exe.n).map(|_| rng.below(PRIME) as i32).collect();
    let (y, f) = exe.run(&x, &t, 20, MODE_EXACT).unwrap();
    assert!(f.iter().all(|&v| v == 0));
    for i in 0..exe.n {
        assert_eq!(y[i], x[i].max(0));
    }
}

#[test]
fn cnn_artifact_matches_rust_plaintext_forward() {
    let dir = ArtifactDir::discover().expect("artifacts built");
    let c = client();
    let exe = CnnExecutable::load_cnn(&c, &dir).unwrap();
    let net = load_weights(&dir.path("weights.bin")).unwrap();
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let b = exe.batch;

    // Exact mode (mode=2): PJRT logits must equal the Rust field-
    // arithmetic forward pass exactly.
    let images: Vec<i32> =
        ds.images[..b * ds.dim].iter().map(|f| f.to_i64() as i32).collect();
    let zeros1 = vec![0i32; b * 8 * 8 * 8];
    let zeros2 = vec![0i32; b * 16 * 4 * 4];
    let out = exe.run(&images, &zeros1, &zeros2, 0, MODE_EXACT).unwrap();
    assert_eq!(out.total_faults(), 0);

    for row in 0..8 {
        let input: Vec<Fp> = ds.image(row).to_vec();
        let want = net.forward_exact(&input);
        let got = &out.logits[row * 10..(row + 1) * 10];
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(*g as i64, w.to_i64(), "row {row}");
        }
    }
}

#[test]
fn cnn_accuracy_flat_then_cliff() {
    // The Fig. 4 shape at smoke scale: accuracy(k=12) ≈ accuracy(exact),
    // accuracy(k=22) ≈ chance.
    let dir = ArtifactDir::discover().expect("artifacts built");
    let c = client();
    let exe = CnnExecutable::load_cnn(&c, &dir).unwrap();
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let b = exe.batch;
    let mut rng = Rng::new(3);

    let images: Vec<i32> =
        ds.images[..b * ds.dim].iter().map(|f| f.to_i64() as i32).collect();
    let labels = &ds.labels[..b];
    let t1: Vec<i32> = (0..b * 512).map(|_| rng.below(PRIME) as i32).collect();
    let t2: Vec<i32> = (0..b * 256).map(|_| rng.below(PRIME) as i32).collect();

    let acc_of = |out: &circa::runtime::ModelOutput| {
        let logits: Vec<Vec<Fp>> = (0..b)
            .map(|i| {
                out.logits[i * 10..(i + 1) * 10].iter().map(|&v| Fp::from_i64(v as i64)).collect()
            })
            .collect();
        accuracy(&logits, labels)
    };

    let exact = exe.run(&images, &t1, &t2, 0, MODE_EXACT).unwrap();
    let k12 = exe.run(&images, &t1, &t2, 12, MODE_POSZERO).unwrap();
    let k22 = exe.run(&images, &t1, &t2, 22, MODE_POSZERO).unwrap();

    let (a_exact, a_12, a_22) = (acc_of(&exact), acc_of(&k12), acc_of(&k22));
    assert!(a_exact > 0.85, "exact accuracy {a_exact}");
    assert!((a_exact - a_12).abs() < 0.05, "k=12 hurt accuracy: {a_exact} vs {a_12}");
    assert!(a_22 < 0.5, "k=22 should collapse: {a_22}");
    assert!(k12.total_faults() > 0);
    assert!(k22.total_faults() > k12.total_faults());
}

#[test]
fn mlp_artifact_loads_and_runs() {
    let dir = ArtifactDir::discover().expect("artifacts built");
    let c = client();
    let exe = CnnExecutable::load_mlp(&c, &dir).unwrap();
    let ds = load_dataset(&dir.path("dataset.bin")).unwrap();
    let b = exe.batch;
    let mut rng = Rng::new(4);
    let images: Vec<i32> =
        ds.images[..b * ds.dim].iter().map(|f| f.to_i64() as i32).collect();
    let t1: Vec<i32> = (0..b * 128).map(|_| rng.below(PRIME) as i32).collect();
    let t2: Vec<i32> = (0..b * 64).map(|_| rng.below(PRIME) as i32).collect();
    let out = exe.run(&images, &t1, &t2, 12, MODE_POSZERO).unwrap();
    assert_eq!(out.logits.len(), b * 10);
    let labels = &ds.labels[..b];
    let logits: Vec<Vec<Fp>> = (0..b)
        .map(|i| out.logits[i * 10..(i + 1) * 10].iter().map(|&v| Fp::from_i64(v as i64)).collect())
        .collect();
    assert!(accuracy(&logits, labels) > 0.8);
}

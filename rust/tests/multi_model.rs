//! Acceptance tests for the multi-model coordinator: two plans of
//! different depths (variants k ∈ {0, 12}) served concurrently over one
//! TCP dealer link — every assembled session bit-matches an inline
//! single-model deal of the same `(base_seed, plan, seq)` — and the
//! cross-model staging guard: a `LayerBatch` tagged for model B can
//! never be staged into model A's bank (fingerprint mismatch → dropped
//! + counted), proven against a deliberately lying dealer.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{MaterialPool, Metrics, ModelRegistry, RefillSource};
use circa::field::Fp;
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::{
    deal_relu_layer_mt, deal_spine, offline_network_mt, run_inference, session_rng, NetworkPlan,
};
use circa::util::bytes::{Reader, Writer};
use circa::util::Rng;
use circa::wire::codec;
use circa::wire::dealer::{spawn_tcp_dealer_multi, RemoteDealer, REQ_RELU_LAYER, REQ_SPINE};
use circa::wire::frame::{Channel, Framed, MemChannel, MsgType};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Model A: 3 linear layers (2 ReLU layers), Circa k=12.
fn plan_a() -> Arc<NetworkPlan> {
    let mut rng = Rng::new(31);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(5, 6, 20, &mut rng)),
        Arc::new(Matrix::random(4, 5, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(
        linears,
        ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
    ))
}

/// Model B: 2 linear layers (1 ReLU layer), Circa k=0 (exact sign).
fn plan_b() -> Arc<NetworkPlan> {
    let mut rng = Rng::new(32);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(4, 6, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(
        linears,
        ReluVariant::TruncatedSign { k: 0, mode: FaultMode::PosZero },
    ))
}

const SEED_A: u64 = 0xA11CE;
const SEED_B: u64 = 0xB0BB1;

fn two_model_registry() -> (Arc<ModelRegistry>, u64, u64) {
    let mut reg = ModelRegistry::new();
    let fa = reg.register(plan_a(), SEED_A, 1.0).unwrap();
    let fb = reg.register(plan_b(), SEED_B, 2.0).unwrap();
    (Arc::new(reg), fa, fb)
}

#[test]
fn two_models_over_one_tcp_dealer_bit_match_inline_single_model_deals() {
    // The tentpole acceptance property: with two registered plans
    // streaming over one TCP dealer, every assembled session of each
    // model is bit-identical (offline bytes + full inference transcript)
    // to an inline single-model deal from that model's own
    // (base_seed, seq) — seq spaces never collide because the base
    // seeds differ per model.
    let (registry, fa, fb) = two_model_registry();
    let handle =
        spawn_tcp_dealer_multi("127.0.0.1:0", registry.clone(), 0xC0DE, 2).expect("bind dealer");
    let addr = handle.addr().to_string();

    let metrics = Arc::new(Metrics::default());
    let reg_c = registry.clone();
    let connect: Arc<dyn Fn() -> circa::util::error::Result<RemoteDealer> + Send + Sync> =
        Arc::new(move || RemoteDealer::connect_tcp(&addr, reg_c.clone()));
    let pool = MaterialPool::start_multi(
        registry.clone(),
        3,
        2,
        RefillSource::remote_single(connect, 2),
        Some(metrics.clone()),
        1,
    );
    pool.wait_ready(3);

    let input: Vec<Fp> = (0..6).map(|j| Fp::from_i64(1400 + 5 * j)).collect();
    let mut rng = Rng::new(6);
    for (fp, plan, seed) in [(fa, plan_a(), SEED_A), (fb, plan_b(), SEED_B)] {
        for seq in 0..3u64 {
            let lease = pool.lease_model(fp, &mut rng);
            assert!(!lease.was_dry, "model {fp:#x} seq {seq}: bank must be fed over TCP");
            let (client, server, offline_bytes) =
                offline_network_mt(&plan, &mut session_rng(seed, seq), 1);
            assert_eq!(lease.session.offline_bytes, offline_bytes, "model {fp:#x} seq {seq}");
            let (wire_logits, wire_stats) =
                run_inference(&lease.session.client, &lease.session.server, &input);
            let (inline_logits, inline_stats) = run_inference(&client, &server, &input);
            assert_eq!(wire_logits, inline_logits, "model {fp:#x} seq {seq}: transcript");
            assert_eq!(wire_stats.bytes_to_client, inline_stats.bytes_to_client);
            assert_eq!(wire_stats.bytes_to_server, inline_stats.bytes_to_server);
        }
    }

    // No cross-model contamination, and both models report their own
    // metrics rows (A has 2 relu banks + spine, B has 1 + spine).
    assert_eq!(pool.fingerprint_drops(), 0);
    let snap = metrics.snapshot();
    assert_eq!(snap.fp_mismatch_drops, 0);
    let row = |fp: u64| snap.models.iter().find(|m| m.fingerprint == fp).expect("model row");
    assert_eq!(row(fa).bank_depths.len(), 3, "model A: spine + 2 relu banks");
    assert_eq!(row(fb).bank_depths.len(), 2, "model B: spine + 1 relu bank");
    assert!(row(fa).layer_entries >= 1);
    assert!(row(fb).layer_entries >= 1);
    assert!(snap.bytes_offline_wire > 0);

    pool.shutdown();
    handle.stop();
}

/// A dealer that handshakes honestly and serves spines honestly, but
/// answers **every** ReLU-layer request with model B's material, tagged
/// with model B's fingerprint — valid, decodable material, just for the
/// wrong model whenever model A asked. Exercises the pool's staging
/// guard end to end.
fn spawn_lying_dealer(registry: Arc<ModelRegistry>, fb: u64) -> Box<dyn Channel> {
    let (coord_end, dealer_end) = MemChannel::pair();
    std::thread::spawn(move || {
        let mut framed = Framed::new(Box::new(dealer_end));
        let Ok(hello) = framed.recv() else { return };
        if hello.msg_type != MsgType::Hello {
            return;
        }
        let set = codec::encode_manifest_set(&registry.manifests()).unwrap();
        if framed.send(MsgType::Hello, &set).is_err() {
            return;
        }
        let entry_b = registry.get(fb).expect("model B registered");
        loop {
            let Ok(frame) = framed.recv() else { return };
            match frame.msg_type {
                MsgType::RequestLayers => {
                    let mut r = Reader::new(&frame.payload);
                    let fp = r.u64().unwrap();
                    let kind = r.u8().unwrap();
                    let layer = r.u32().unwrap() as usize;
                    let count = r.u32().unwrap();
                    let seqs: Vec<u64> = (0..count).map(|_| r.u64().unwrap()).collect();
                    for seq in seqs {
                        if kind == REQ_SPINE {
                            // Honest spine for whichever model asked.
                            let entry = registry.get(fp).expect("requested model");
                            let spine =
                                deal_spine(&entry.plan, &mut session_rng(entry.base_seed, seq));
                            let mut w = Writer::new();
                            codec::put_spine(&mut w, fp, seq, &spine);
                            if framed.send(MsgType::Spine, &w.buf).is_err() {
                                return;
                            }
                        } else {
                            assert_eq!(kind, REQ_RELU_LAYER);
                            // The lie: model B's layer, tagged for B,
                            // whatever model was asked for.
                            let (cm, sm) = deal_relu_layer_mt(
                                &entry_b.plan,
                                &mut session_rng(entry_b.base_seed, seq),
                                layer.min(entry_b.plan.n_relu_layers() - 1),
                                1,
                            );
                            let mut w = Writer::new();
                            codec::put_layer_batch(
                                &mut w,
                                fb,
                                layer.min(entry_b.plan.n_relu_layers() - 1) as u32,
                                seq,
                                &cm,
                                &sm,
                            );
                            if framed.send(MsgType::LayerBatch, &w.buf).is_err() {
                                return;
                            }
                        }
                    }
                }
                MsgType::Bye => return,
                _ => return,
            }
        }
    });
    Box::new(coord_end)
}

#[test]
fn cross_model_layer_batch_is_dropped_and_counted_never_staged() {
    // Two same-depth plans so a lying dealer can echo the requested
    // (layer, seq) with *valid* model-B material. Model A's ReLU bank
    // must stay empty — every B-tagged unit is dropped and counted —
    // while model B (served honestly by the same lying dealer) still
    // assembles sessions that bit-match inline deals.
    let pa = {
        let mut rng = Rng::new(41);
        let linears: Vec<Arc<dyn LinearOp>> = vec![
            Arc::new(Matrix::random(5, 6, 20, &mut rng)),
            Arc::new(Matrix::random(3, 5, 20, &mut rng)),
        ];
        Arc::new(NetworkPlan::unscaled(
            linears,
            ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
        ))
    };
    let pb = plan_b();
    let mut reg = ModelRegistry::new();
    let fa = reg.register(pa, SEED_A, 1.0).unwrap();
    // B's higher demand weight makes the scheduler fill B's banks before
    // hammering A's permanently-failing relu bank — the weighting is
    // exactly what keeps a poisoned (model, layer) pair from starving a
    // healthy model on the same connection.
    let fb = reg.register(pb.clone(), SEED_B, 3.0).unwrap();
    let registry = Arc::new(reg);

    let metrics = Arc::new(Metrics::default());
    let reg_c = registry.clone();
    let connect: Arc<dyn Fn() -> circa::util::error::Result<RemoteDealer> + Send + Sync> =
        Arc::new(move || {
            let chan = spawn_lying_dealer(reg_c.clone(), fb);
            RemoteDealer::connect(chan, reg_c.clone())
        });
    let pool = MaterialPool::start_multi(
        registry,
        2,
        1,
        RefillSource::remote_single(connect, 2),
        Some(metrics.clone()),
        1,
    );

    // Wait (bounded) until the guard has fired and model B is ready.
    let deadline = Instant::now() + Duration::from_secs(20);
    while (pool.fingerprint_drops() < 2 || pool.banked_model(fb) < 1)
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        pool.fingerprint_drops() >= 2,
        "B-tagged units for model A must be dropped and counted (got {})",
        pool.fingerprint_drops()
    );
    assert!(
        metrics.snapshot().fp_mismatch_drops >= 2,
        "drops surface in metrics too"
    );

    // Model A's ReLU bank never staged a foreign unit (its spine bank
    // may fill — spines are served honestly).
    let depths_a = pool.bank_depths_model(fa);
    assert_eq!(depths_a[1], 0, "model A relu bank must stay empty: {depths_a:?}");
    assert_eq!(pool.banked_model(fa), 0, "no model-A session can assemble");

    // Model B is fully served by the same connection and still
    // bit-matches the inline deal of its own namespace.
    assert!(pool.banked_model(fb) >= 1, "model B must be unaffected");
    let mut rng = Rng::new(3);
    let lease = pool.lease_model(fb, &mut rng);
    assert!(!lease.was_dry);
    let (client, server, offline_bytes) =
        offline_network_mt(&pb, &mut session_rng(SEED_B, 0), 1);
    assert_eq!(lease.session.offline_bytes, offline_bytes);
    let input: Vec<Fp> = (0..6).map(|j| Fp::from_i64(1100 + 3 * j)).collect();
    let (bank_logits, _) = run_inference(&lease.session.client, &lease.session.server, &input);
    let (inline_logits, _) = run_inference(&client, &server, &input);
    assert_eq!(bank_logits, inline_logits);

    pool.shutdown();
}

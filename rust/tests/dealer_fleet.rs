//! Integration tests for the multi-dealer refill fleet: real TCP
//! dealers, claim partitioning, mid-run dealer death, and PSK-
//! authenticated links.
//!
//! The load-bearing property throughout is seq-addressed dealing
//! purity: entry `(model, bank, seq)` is a pure function of the model's
//! registry base seed, so a bank filled by three dealers must be
//! byte-identical to one filled by a single dealer — and to the inline
//! deal — seq for seq. That purity is what makes work stealing and
//! failure handoff safe, and it is what these tests pin end to end.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::coordinator::{
    DealerEndpoint, MaterialPool, ModelConfig, ModelRegistry, PiService, PoolTuning,
    RefillSource, ServiceConfig,
};
use circa::field::Fp;
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::server::{offline_network_mt, run_inference, session_rng, NetworkPlan};
use circa::util::Rng;
use circa::wire::dealer::spawn_tcp_dealer_multi_psk;
use std::sync::Arc;
use std::time::Duration;

fn tiny_plan() -> Arc<NetworkPlan> {
    let mut rng = Rng::new(1);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(4, 6, 10, &mut rng)),
        Arc::new(Matrix::random(3, 4, 10, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(linears, ReluVariant::BaselineRelu))
}

fn other_plan() -> Arc<NetworkPlan> {
    let mut rng = Rng::new(2);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(5, 6, 10, &mut rng)),
        Arc::new(Matrix::random(3, 5, 10, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(
        linears,
        ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero },
    ))
}

/// Two-model registry shared by every dealer and the coordinator (same
/// process, same `Arc` — the manifest-set handshake still verifies it).
fn fleet_registry() -> (Arc<ModelRegistry>, u64, u64) {
    let mut reg = ModelRegistry::new();
    let fa = reg.register(tiny_plan(), 0xA11CE, 1.0).unwrap();
    let fb = reg.register(other_plan(), 0xB0B, 1.0).unwrap();
    (Arc::new(reg), fa, fb)
}

fn input() -> Vec<Fp> {
    (0..6).map(|i| Fp::from_i64(800 + 7 * i)).collect()
}

/// Banks reached target, so the remote-claim ledger must be fully
/// resolved: no live tickets, no in-flight units anywhere.
fn assert_ledger_quiescent(pool: &MaterialPool) {
    assert_eq!(pool.outstanding_claims(), (0, 0), "claim records outstanding");
    assert_eq!(pool.in_flight_total(), 0, "in-flight units outstanding");
}

/// Lease every banked seq of `model` and pin it bit-for-bit against the
/// inline deal from the same `(base_seed, seq)` session RNG.
fn assert_leases_match_inline(pool: &MaterialPool, model: u64, base_seed: u64, n: usize) {
    let plan = pool.registry().get(model).unwrap().plan.clone();
    let mut rng = Rng::new(99);
    let x = input();
    for seq in 0..n as u64 {
        let lease = pool.lease_model(model, &mut rng);
        assert!(!lease.was_dry, "model {model:#x} seq {seq} leased dry");
        let (client, server, offline_bytes) =
            offline_network_mt(&plan, &mut session_rng(base_seed, seq), 1);
        assert_eq!(lease.session.offline_bytes, offline_bytes, "model {model:#x} seq {seq}");
        let (fleet_logits, _) = run_inference(&lease.session.client, &lease.session.server, &x);
        let (inline_logits, _) = run_inference(&client, &server, &x);
        assert_eq!(fleet_logits, inline_logits, "model {model:#x} seq {seq}");
    }
}

#[test]
fn three_dealer_fleet_banks_bit_identical_to_single_dealer() {
    // One dealer vs a three-dealer fleet over real TCP sockets: both
    // pools must fill, and every leased seq of every model must be
    // bit-identical to the inline deal (hence to each other) — the
    // partitioning across links is unobservable in the material.
    let (registry, fa, fb) = fleet_registry();
    let handles: Vec<_> = (0..3)
        .map(|i| {
            spawn_tcp_dealer_multi_psk(
                "127.0.0.1:0",
                registry.clone(),
                0xD0 + i,
                1,
                None,
            )
            .expect("bind dealer")
        })
        .collect();
    let addrs: Vec<String> = handles.iter().map(|h| h.addr().to_string()).collect();

    let target = 4;
    let single = MaterialPool::start_multi(
        registry.clone(),
        target,
        1,
        RefillSource::remote(
            vec![DealerEndpoint::tcp(&addrs[0], registry.clone(), None)],
            2,
        ),
        None,
        1,
    );
    let endpoints: Vec<DealerEndpoint> =
        addrs.iter().map(|a| DealerEndpoint::tcp(a, registry.clone(), None)).collect();
    let fleet = MaterialPool::start_multi(
        registry.clone(),
        target,
        3,
        RefillSource::remote(endpoints, 2),
        None,
        1,
    );
    single.wait_ready(target);
    fleet.wait_ready(target);
    assert_eq!(single.banked(), target);
    assert_eq!(fleet.banked(), target);
    assert_ledger_quiescent(&single);
    assert_ledger_quiescent(&fleet);
    assert_eq!(fleet.link_states().len(), 3, "one fleet link per endpoint");

    for (fp, seed) in [(fa, 0xA11CEu64), (fb, 0xB0B)] {
        assert_leases_match_inline(&single, fp, seed, target);
        assert_leases_match_inline(&fleet, fp, seed, target);
    }
    assert_eq!(single.fingerprint_drops(), 0);
    assert_eq!(fleet.fingerprint_drops(), 0);
    single.shutdown();
    fleet.shutdown();
    for h in handles {
        h.stop();
    }
}

#[test]
fn dealer_killed_mid_run_hands_off_and_fleet_completes() {
    // Two live TCP dealers; one is killed (sockets severed, listener
    // down) while the pool is refilling. The surviving link must absorb
    // the dead link's claims — via EOF-triggered failure handoff or the
    // steal path — and fill every bank to target with zero lost and
    // zero double-staged seqs: the ledger ends exactly resolved and
    // every leased seq is bit-identical to the inline deal.
    let (registry, fa, fb) = fleet_registry();
    let h0 = spawn_tcp_dealer_multi_psk("127.0.0.1:0", registry.clone(), 0xE0, 1, None)
        .expect("bind dealer 0");
    let h1 = spawn_tcp_dealer_multi_psk("127.0.0.1:0", registry.clone(), 0xE1, 1, None)
        .expect("bind dealer 1");
    let addr0 = h0.addr().to_string();
    let addr1 = h1.addr().to_string();

    let target = 6;
    let endpoints = vec![
        DealerEndpoint::tcp(&addr0, registry.clone(), None),
        DealerEndpoint::tcp(&addr1, registry.clone(), None),
    ];
    // Short steal_after: even a claim stranded in a severed socket's
    // read is re-issued quickly.
    let tuning = PoolTuning {
        steal_after: Duration::from_millis(150),
        demand_half_life: Duration::from_secs(10),
    };
    let pool = MaterialPool::start_multi_tuned(
        registry.clone(),
        target,
        2,
        RefillSource::remote(endpoints, 2),
        None,
        1,
        tuning,
    );

    // Let the refill get underway on both links, then kill dealer 1.
    pool.wait_ready(2);
    h1.kill();

    // The fleet must still reach target from the survivor alone.
    pool.wait_ready(target);
    assert_eq!(pool.banked(), target);
    assert_ledger_quiescent(&pool);

    // Exactness: seqs 0..target lease in order, each bit-identical to
    // the inline deal — no seq was lost to the dead dealer and none was
    // staged twice (a duplicate would have tripped the claim
    // accounting before ever assembling).
    for (fp, seed) in [(fa, 0xA11CEu64), (fb, 0xB0B)] {
        assert_leases_match_inline(&pool, fp, seed, target);
    }
    assert_eq!(pool.fingerprint_drops(), 0);
    pool.shutdown();
    h0.stop();
}

#[test]
fn psk_fleet_serves_end_to_end_through_the_service() {
    // Service-level plumbing: ServiceConfig.dealer_addrs +
    // ServiceConfig.dealer_psk stand up a two-link authenticated fleet,
    // warm both models' banks over it, and serve mixed traffic.
    let key = [0x42u8; 16];
    let (registry, _, _) = fleet_registry();
    let h0 = spawn_tcp_dealer_multi_psk("127.0.0.1:0", registry.clone(), 0xF0, 1, Some(key))
        .expect("bind dealer 0");
    let h1 = spawn_tcp_dealer_multi_psk("127.0.0.1:0", registry.clone(), 0xF1, 1, Some(key))
        .expect("bind dealer 1");
    let dealer_addrs = vec![h0.addr().to_string(), h1.addr().to_string()];

    let models: Vec<(Arc<NetworkPlan>, ModelConfig)> = registry
        .entries()
        .iter()
        .map(|e| {
            (e.plan.clone(), ModelConfig { base_seed: Some(e.base_seed), demand: e.demand })
        })
        .collect();
    let svc = PiService::start_multi(models, ServiceConfig {
        workers: 2,
        pool_target: 4,
        pool_dealers: 2,
        dealer_addrs,
        dealer_psk: Some(key),
        ..Default::default()
    })
    .expect("start service over PSK fleet");
    svc.warmup(2);
    let fps = svc.models();
    assert_eq!(fps.len(), 2);

    let rxs: Vec<_> = (0..6)
        .map(|i| {
            let m = i % fps.len();
            (m, svc.submit_to(fps[m], input()).expect("known model"))
        })
        .collect();
    for (m, rx) in rxs {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.model, fps[m], "response carries its model fingerprint");
        assert!(!resp.logits.is_empty());
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.links.len(), 2, "one metrics row per fleet link");
    assert!(
        snap.links.iter().map(|l| l.fetches).sum::<u64>() >= 1,
        "warmup refilled over the authenticated links"
    );
    svc.shutdown();
    h0.stop();
    h1.stop();
}

//! Property-based tests (hand-rolled — proptest is not in the offline
//! vendor set): randomized invariants over the GC builders, the garbling
//! scheme, the protocol algebra, and failure injection.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::field::{random_fp, Fp, PRIME};
use circa::gc::build::{bits_to_u64, u64_to_bits, Builder};
use circa::gc::{evaluate, garble};
use circa::protocol::offline::offline_relu_layer;
use circa::protocol::online::online_relu_layer;
use circa::ss::{reconstruct_vec, SharePair};
use circa::util::Rng;

/// Random-width adders/subtractors/comparators vs u64 arithmetic.
#[test]
fn prop_bus_arithmetic_matches_u64() {
    let mut rng = Rng::new(1);
    for trial in 0..60 {
        let m = 1 + rng.below_usize(24);
        let a_val = rng.below(1 << m);
        let b_val = rng.below(1 << m);

        let mut bld = Builder::new();
        let a = bld.input_bus(m);
        let b = bld.input_bus(m);
        let (sum, carry) = bld.add(&a, &b);
        let (diff, borrow) = bld.sub(&a, &b);
        let geq = bld.geq(&a, &b);
        bld.output_bus(&sum);
        bld.output(carry);
        bld.output_bus(&diff);
        bld.output(borrow);
        bld.output(geq);
        let c = bld.build();

        let mut inputs = u64_to_bits(a_val, m);
        inputs.extend(u64_to_bits(b_val, m));
        let out = c.eval_plain(&inputs);

        let sum_got = bits_to_u64(&out[..m]) | ((out[m] as u64) << m);
        assert_eq!(sum_got, a_val + b_val, "trial {trial} m={m} add");
        let diff_got = bits_to_u64(&out[m + 1..2 * m + 1]);
        assert_eq!(diff_got, a_val.wrapping_sub(b_val) & ((1u64 << m) - 1), "sub");
        assert_eq!(out[2 * m + 1], a_val < b_val, "borrow");
        assert_eq!(out[2 * m + 2], a_val >= b_val, "geq");
    }
}

/// Garbling correctness on random circuits with random input vectors —
/// the garbled evaluation must equal plain evaluation every time.
#[test]
fn prop_garble_eval_equals_plain() {
    let mut rng = Rng::new(2);
    for _ in 0..20 {
        let n_in = 2 + rng.below_usize(8);
        let mut bld = Builder::new();
        let mut pool: Vec<_> = (0..n_in).map(|_| bld.input()).collect();
        for _ in 0..60 {
            let a = pool[rng.below_usize(pool.len())];
            let b = pool[rng.below_usize(pool.len())];
            let v = match rng.below(4) {
                0 => bld.xor(a, b),
                1 => bld.and(a, b),
                2 => bld.or(a, b),
                _ => bld.not(a),
            };
            pool.push(v);
        }
        for _ in 0..6 {
            let o = pool[rng.below_usize(pool.len())];
            bld.output(o);
        }
        let c = bld.build();
        let (gc, enc) = garble(&c, &mut rng);
        for _ in 0..5 {
            let inputs: Vec<bool> = (0..n_in).map(|_| rng.bool()).collect();
            let got = gc.decode(&evaluate(&c, &gc, &enc.encode_all(&inputs)));
            assert_eq!(got, c.eval_plain(&inputs));
        }
    }
}

/// Tampering with any single table entry must disturb the evaluation of
/// the gate it belongs to (failure injection on the GC substrate).
#[test]
fn prop_table_tamper_detected_by_label_mismatch() {
    let mut rng = Rng::new(3);
    let mut bld = Builder::new();
    let a = bld.input_bus(8);
    let b = bld.input_bus(8);
    let geq = bld.geq(&a, &b);
    bld.output(geq);
    let c = bld.build();
    let (gc, enc) = garble(&c, &mut rng);
    for gate in 0..gc.table.len() {
        // Tamper both ciphertexts of one gate. A tampered row only
        // affects evaluations whose color bits select it, so require the
        // corruption to surface on at least one of several random inputs.
        let mut bad_gc = gc.clone();
        bad_gc.table[gate][0] = circa::prf::Label(bad_gc.table[gate][0].0 ^ 0xDEAD);
        bad_gc.table[gate][1] = circa::prf::Label(bad_gc.table[gate][1].0 ^ 0xBEEF);
        let mut detected = false;
        for _ in 0..16 {
            let mut inputs = u64_to_bits(rng.below(256), 8);
            inputs.extend(u64_to_bits(rng.below(256), 8));
            let labels = enc.encode_all(&inputs);
            if evaluate(&c, &gc, &labels) != evaluate(&c, &bad_gc, &labels) {
                detected = true;
                break;
            }
        }
        assert!(detected, "tamper at gate {gate} went unnoticed on 16 inputs");
    }
}

/// Protocol algebra: for ANY share split of the same x, the reconstructed
/// stochastic ReLU differs only through the sign decision (values are
/// x or 0 / passed-through-x — never garbage).
#[test]
fn prop_online_outputs_are_x_or_zero() {
    let mut rng = Rng::new(4);
    for mode in [FaultMode::PosZero, FaultMode::NegPass] {
        let variant = ReluVariant::TruncatedSign { k: 16, mode };
        for _ in 0..10 {
            let vals: Vec<i64> =
                (0..16).map(|_| rng.below(1 << 18) as i64 - (1 << 17)).collect();
            let shares: Vec<SharePair> =
                vals.iter().map(|&v| SharePair::share(Fp::from_i64(v), &mut rng)).collect();
            let xc: Vec<Fp> = shares.iter().map(|s| s.client).collect();
            let xs: Vec<Fp> = shares.iter().map(|s| s.server).collect();
            let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng);
            let (yc, ys, _) = online_relu_layer(&cm, &sm, &xc, &xs);
            let ys_rec = reconstruct_vec(&yc, &ys);
            for (y, &x) in ys_rec.iter().zip(&vals) {
                let got = y.to_i64();
                assert!(got == x || got == 0, "y={got} for x={x}");
            }
        }
    }
}

/// Share-split invariance: the *exact-regime* outputs (|x| ≥ 2^k) must
/// be identical across arbitrary re-sharings of the same inputs.
#[test]
fn prop_share_split_invariance_outside_trunc_range() {
    let mut rng = Rng::new(5);
    let k = 12u32;
    let variant = ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero };
    let vals: Vec<i64> = (0..8)
        .map(|_| {
            let mag = (1i64 << k) + rng.below(1 << 20) as i64;
            if rng.bool() {
                mag
            } else {
                -mag
            }
        })
        .collect();
    let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
    for _ in 0..8 {
        let shares: Vec<SharePair> =
            vals.iter().map(|&v| SharePair::share(Fp::from_i64(v), &mut rng)).collect();
        let xc: Vec<Fp> = shares.iter().map(|s| s.client).collect();
        let xs: Vec<Fp> = shares.iter().map(|s| s.server).collect();
        let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng);
        let (yc, ys, _) = online_relu_layer(&cm, &sm, &xc, &xs);
        let got: Vec<i64> = reconstruct_vec(&yc, &ys).iter().map(|y| y.to_i64()).collect();
        assert_eq!(got, want);
    }
}

/// Field sanity at scale: uniform elements round-trip the signed codec
/// and the share codec.
#[test]
fn prop_field_codecs_roundtrip() {
    let mut rng = Rng::new(6);
    for _ in 0..5000 {
        let x = random_fp(&mut rng);
        assert_eq!(Fp::from_i64(x.to_i64()), x);
        let t = random_fp(&mut rng);
        let sh = SharePair::share_with_t(x, t);
        assert_eq!(sh.reconstruct(), x);
        assert!(x.raw() < PRIME);
    }
}

//! Contract tests for the cross-request batched online phase: executing
//! R concurrent inferences as one batched walk
//! (`run_inference_multi`) must be **bit-identical**, request by
//! request, to R independent `run_inference` calls on the same leased
//! sessions — for every variant and truncation level — and the
//! aggregated wire-byte ledger must be the exact sum of the per-request
//! ledgers. This is the property the router's batched dispatch path
//! stands on.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::field::Fp;
use circa::protocol::client::ClientNet;
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::online::{online_relu_layer, online_relu_layer_multi, OnlineScratch};
use circa::protocol::offline::offline_relu_layer;
use circa::protocol::server::{
    offline_network_mt, run_inference, run_inference_multi, session_rng, NetworkPlan, ServerNet,
};
use circa::util::Rng;
use std::sync::Arc;

fn variants() -> Vec<ReluVariant> {
    let mut v = vec![
        ReluVariant::BaselineRelu,
        ReluVariant::NaiveSign,
        ReluVariant::StochasticSign { mode: FaultMode::PosZero },
    ];
    for k in [0u32, 8, 12] {
        v.push(ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero });
        v.push(ReluVariant::TruncatedSign { k, mode: FaultMode::NegPass });
    }
    v
}

/// 6 → 5 → relu → 5 → 4 → relu → 4 → 3, optionally rescaled.
fn plan(variant: ReluVariant, seed: u64, rescaled: bool) -> NetworkPlan {
    let mut rng = Rng::new(seed);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(5, 6, 20, &mut rng)),
        Arc::new(Matrix::random(4, 5, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    let rescale_bits = if rescaled { vec![1, 2] } else { Vec::new() };
    NetworkPlan { linears, variant, rescale_bits }
}

/// Deal R sessions the way the pool leases them: one session per
/// request, seq-addressed under a shared base seed.
fn lease_sessions(p: &NetworkPlan, base_seed: u64, r_count: usize) -> Vec<(ClientNet, ServerNet)> {
    (0..r_count)
        .map(|seq| {
            let (cn, sn, _) = offline_network_mt(p, &mut session_rng(base_seed, seq as u64), 1);
            (cn, sn)
        })
        .collect()
}

/// Each request gets its own distinct input.
fn inputs_for(r_count: usize) -> Vec<Vec<Fp>> {
    (0..r_count)
        .map(|r| (0..6).map(|j| Fp::from_i64(900 + 101 * r as i64 + 7 * j)).collect())
        .collect()
}

#[test]
fn batched_inference_bit_identical_to_per_request_all_variants() {
    for (vi, variant) in variants().into_iter().enumerate() {
        for r_count in [1usize, 2, 8] {
            let p = plan(variant, 40 + vi as u64, false);
            let sessions = lease_sessions(&p, 0xF00D + vi as u64, r_count);
            let inputs = inputs_for(r_count);

            // Oracle: R independent per-request runs, one per session.
            let mut want = Vec::new();
            let (mut sum_c, mut sum_s) = (0u64, 0u64);
            for ((cn, sn), input) in sessions.iter().zip(&inputs) {
                let (logits, st) = run_inference(cn, sn, input);
                sum_c += st.bytes_to_client;
                sum_s += st.bytes_to_server;
                want.push(logits);
            }

            let refs: Vec<(&ClientNet, &ServerNet)> =
                sessions.iter().map(|(cn, sn)| (cn, sn)).collect();
            let in_refs: Vec<&[Fp]> = inputs.iter().map(|v| v.as_slice()).collect();
            let (got, st) = run_inference_multi(&refs, &in_refs, 1);
            for r in 0..r_count {
                assert_eq!(got[r], want[r], "{variant:?} R={r_count}: request {r} logits");
            }
            assert_eq!(st.bytes_to_client, sum_c, "{variant:?} R={r_count}: bytes to client");
            assert_eq!(st.bytes_to_server, sum_s, "{variant:?} R={r_count}: bytes to server");
        }
    }
}

#[test]
fn batched_inference_matches_on_rescaled_plan_and_any_thread_count() {
    let variant = ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero };
    let p = plan(variant, 77, true);
    let r_count = 4;
    let sessions = lease_sessions(&p, 0xCAFE, r_count);
    let inputs = inputs_for(r_count);
    let want: Vec<Vec<Fp>> = sessions
        .iter()
        .zip(&inputs)
        .map(|((cn, sn), input)| run_inference(cn, sn, input).0)
        .collect();
    let refs: Vec<(&ClientNet, &ServerNet)> = sessions.iter().map(|(cn, sn)| (cn, sn)).collect();
    let in_refs: Vec<&[Fp]> = inputs.iter().map(|v| v.as_slice()).collect();
    // The chunk-parallel linear spine must not change a single bit.
    for lin_threads in [1usize, 2, 8] {
        let (got, _) = run_inference_multi(&refs, &in_refs, lin_threads);
        assert_eq!(got, want, "lin_threads={lin_threads}");
    }
}

#[test]
fn batched_relu_layer_stats_sum_exactly_per_variant() {
    // Layer-level: fused rounds keep the single-request round count
    // while the byte ledger sums exactly — for k ∈ {0, 8, 12} Circa
    // variants (4 rounds) and the baseline (2 rounds).
    let cases = [
        (ReluVariant::BaselineRelu, 2u32),
        (ReluVariant::TruncatedSign { k: 0, mode: FaultMode::PosZero }, 4),
        (ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero }, 4),
        (ReluVariant::TruncatedSign { k: 12, mode: FaultMode::NegPass }, 4),
    ];
    for (ci, (variant, want_rounds)) in cases.into_iter().enumerate() {
        for r_count in [2usize, 8] {
            let mut rng = Rng::new(0x5EED + ci as u64);
            let n = 6;
            let mut mats = Vec::new();
            let mut shares: Vec<(Vec<Fp>, Vec<Fp>)> = Vec::new();
            for _ in 0..r_count {
                let xc: Vec<Fp> = (0..n).map(|_| circa::field::random_fp(&mut rng)).collect();
                let xs: Vec<Fp> = (0..n).map(|_| circa::field::random_fp(&mut rng)).collect();
                mats.push(offline_relu_layer(variant, &xc, &mut rng));
                shares.push((xc, xs));
            }
            let mut per_req = Vec::new();
            for ((cm, sm), (xc, xs)) in mats.iter().zip(&shares) {
                per_req.push(online_relu_layer(cm, sm, xc, xs));
            }
            let cms: Vec<_> = mats.iter().map(|(cm, _)| cm).collect();
            let sms: Vec<_> = mats.iter().map(|(_, sm)| sm).collect();
            let xcs: Vec<&[Fp]> = shares.iter().map(|(xc, _)| xc.as_slice()).collect();
            let xss: Vec<&[Fp]> = shares.iter().map(|(_, xs)| xs.as_slice()).collect();
            let mut scratch = OnlineScratch::default();
            let (yc, ys, st) = online_relu_layer_multi(&cms, &sms, &xcs, &xss, &mut scratch);
            assert_eq!(st.rounds, want_rounds, "{variant:?}: fused round count");
            let sum_c: u64 = per_req.iter().map(|(_, _, s)| s.bytes_to_client).sum();
            let sum_s: u64 = per_req.iter().map(|(_, _, s)| s.bytes_to_server).sum();
            assert_eq!(st.bytes_to_client, sum_c, "{variant:?} R={r_count}");
            assert_eq!(st.bytes_to_server, sum_s, "{variant:?} R={r_count}");
            for (r, (wc, ws, _)) in per_req.iter().enumerate() {
                assert_eq!(&yc[r], wc, "{variant:?} R={r_count}: client shares {r}");
                assert_eq!(&ys[r], ws, "{variant:?} R={r_count}: server shares {r}");
            }
        }
    }
}

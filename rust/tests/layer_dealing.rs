//! Contract tests for the per-layer forked session schedule: any single
//! ReLU layer (or the linear spine) dealt standalone must be
//! **bit-identical** to the same piece inside a whole-session deal from
//! the same session RNG — for every variant and truncation level — and a
//! session assembled from standalone pieces must reproduce the whole
//! deal's inference transcript exactly. This is the property the
//! layer-sharded material pool and the dealer's `RequestLayers`
//! streaming round stand on.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::field::Fp;
use circa::protocol::client::{ClientLayer, ClientNet};
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::offline::{ClientReluMaterial, ServerReluMaterial};
use circa::protocol::server::{
    assemble_session, deal_relu_layer_mt, deal_spine, offline_network_mt, run_inference,
    session_rng, NetworkPlan, ServerLayer, ServerNet,
};
use circa::util::Rng;
use std::sync::Arc;

fn all_variants() -> Vec<ReluVariant> {
    let mut v = vec![
        ReluVariant::BaselineRelu,
        ReluVariant::NaiveSign,
        ReluVariant::StochasticSign { mode: FaultMode::PosZero },
        ReluVariant::StochasticSign { mode: FaultMode::NegPass },
    ];
    for k in [0u32, 8, 12] {
        v.push(ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero });
        v.push(ReluVariant::TruncatedSign { k, mode: FaultMode::NegPass });
    }
    v
}

/// 6 → 5 → relu → 5 → 4 → relu → 4 → 3, optionally with a rescale
/// schedule (the chain peek must honor the client-side truncation).
fn plan(variant: ReluVariant, seed: u64, rescaled: bool) -> NetworkPlan {
    let mut rng = Rng::new(seed);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(5, 6, 20, &mut rng)),
        Arc::new(Matrix::random(4, 5, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    let rescale_bits = if rescaled { vec![1, 2] } else { Vec::new() };
    NetworkPlan { linears, variant, rescale_bits }
}

fn client_relus(net: &ClientNet) -> Vec<&ClientReluMaterial> {
    net.layers
        .iter()
        .filter_map(|l| match l {
            ClientLayer::Relu(m) => Some(m.as_ref()),
            ClientLayer::Linear { .. } => None,
        })
        .collect()
}

fn server_relus(net: &ServerNet) -> Vec<&ServerReluMaterial> {
    net.layers
        .iter()
        .filter_map(|l| match l {
            ServerLayer::Relu { mat, .. } => Some(mat.as_ref()),
            ServerLayer::Linear { .. } => None,
        })
        .collect()
}

fn assert_layer_identical(
    tag: &str,
    (cm, sm): &(ClientReluMaterial, ServerReluMaterial),
    full_c: &ClientReluMaterial,
    full_s: &ServerReluMaterial,
) {
    assert_eq!(cm.gc.tables(), full_c.gc.tables(), "{tag}: tables");
    assert_eq!(cm.gc.output_decode(), full_c.gc.output_decode(), "{tag}: decode");
    assert_eq!(cm.client_labels, full_c.client_labels, "{tag}: client labels");
    assert_eq!(cm.r_v, full_c.r_v, "{tag}: r_v");
    assert_eq!(cm.r_out, full_c.r_out, "{tag}: r_out");
    assert_eq!(cm.offline_bytes, full_c.offline_bytes, "{tag}: offline bytes");
    assert_eq!(sm.encodings.label0(), full_s.encodings.label0(), "{tag}: label0 arena");
    assert_eq!(
        sm.encodings.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
        full_s.encodings.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
        "{tag}: deltas"
    );
    assert_eq!(sm.output_decode, full_s.output_decode, "{tag}: server decode");
    assert_eq!(cm.triples.len(), full_c.triples.len(), "{tag}: triple count");
    for (i, (a, b)) in cm.triples.iter().zip(&full_c.triples).enumerate() {
        assert_eq!((a.a, a.b, a.ab), (b.a, b.b, b.ab), "{tag}: client triple {i}");
    }
    for (i, (a, b)) in sm.triples.iter().zip(&full_s.triples).enumerate() {
        assert_eq!((a.a, a.b, a.ab), (b.a, b.b, b.ab), "{tag}: server triple {i}");
    }
}

#[test]
fn standalone_layer_matches_in_session_deal_all_variants() {
    for (vi, variant) in all_variants().into_iter().enumerate() {
        let p = plan(variant, 60 + vi as u64, vi % 2 == 0);
        let base_seed = 0xA11 + vi as u64;
        let seq = 5u64;
        let (cn, sn, _) = offline_network_mt(&p, &mut session_rng(base_seed, seq), 1);
        let full_c = client_relus(&cn);
        let full_s = server_relus(&sn);
        for li in 0..p.n_relu_layers() {
            // Standalone deal fanned over 4 threads vs the 1-thread
            // whole-session deal above: the per-layer forks plus the
            // column schedule make them bit-identical.
            let piece = deal_relu_layer_mt(&p, &mut session_rng(base_seed, seq), li, 4);
            assert_layer_identical(
                &format!("{variant:?} layer {li}"),
                &piece,
                full_c[li],
                full_s[li],
            );
        }
    }
}

#[test]
fn standalone_spine_matches_in_session_deal() {
    let p = plan(ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero }, 91, true);
    let base_seed = 0xB22;
    let seq = 2u64;
    let (cn, sn, total_bytes) = offline_network_mt(&p, &mut session_rng(base_seed, seq), 1);
    let spine = deal_spine(&p, &mut session_rng(base_seed, seq));
    assert_eq!(spine.slots.len(), p.linears.len());

    // Every linear slot must match the whole deal's linear layers.
    let mut slot = 0usize;
    for (cl, sl) in cn.layers.iter().zip(&sn.layers) {
        if let (ClientLayer::Linear { r, x_share }, ServerLayer::Linear { s, .. }) = (cl, sl) {
            assert_eq!(&spine.slots[slot].r, r, "slot {slot}: mask");
            assert_eq!(&spine.slots[slot].x_share, x_share, "slot {slot}: x share");
            assert_eq!(&spine.slots[slot].s, s, "slot {slot}: blind");
            slot += 1;
        }
    }
    assert_eq!(slot, p.linears.len());

    // The byte ledger decomposes exactly: spine HE bytes + per-layer
    // ReLU bytes = whole-session offline bytes.
    let layer_bytes: u64 = client_relus(&cn).iter().map(|c| c.offline_bytes).sum();
    assert_eq!(spine.he_bytes + layer_bytes, total_bytes);
}

#[test]
fn assembled_from_standalone_pieces_matches_whole_deal_transcript() {
    let p = plan(ReluVariant::TruncatedSign { k: 12, mode: FaultMode::NegPass }, 17, true);
    let base_seed = 0xC33;
    let seq = 9u64;
    let (cn, sn, total_bytes) = offline_network_mt(&p, &mut session_rng(base_seed, seq), 2);

    let spine = deal_spine(&p, &mut session_rng(base_seed, seq));
    let relus: Vec<_> = (0..p.n_relu_layers())
        .map(|li| deal_relu_layer_mt(&p, &mut session_rng(base_seed, seq), li, 3))
        .collect();
    let (cn2, sn2, bytes2) = assemble_session(&p, spine, relus);
    assert_eq!(bytes2, total_bytes);

    let input: Vec<Fp> = (0..6).map(|j| Fp::from_i64(1700 + 11 * j)).collect();
    let (logits_a, stats_a) = run_inference(&cn, &sn, &input);
    let (logits_b, stats_b) = run_inference(&cn2, &sn2, &input);
    assert_eq!(logits_a, logits_b, "transcript logits");
    assert_eq!(stats_a.bytes_to_client, stats_b.bytes_to_client);
    assert_eq!(stats_a.bytes_to_server, stats_b.bytes_to_server);
}

//! Equivalence proof for the layer-batched offline+online data plane:
//! `offline_relu_layer`/`online_relu_layer` must be **bit-identical** to
//! a per-ReLU reference built from the low-level primitives
//! (`garble_with_scratch`, `ot_choose`, `evaluate_with_scratch`, per-ReLU
//! `Vec` material) — same output shares, same offline byte ledger, same
//! online byte counts — for every variant and truncation level, under a
//! seeded RNG.
//!
//! **Re-anchor (one-time, column schedule):** the offline phase moved
//! from a per-ReLU RNG interleave (garble, r_v, r_out, triple — per ReLU)
//! to the column-wise schedule documented in `protocol::offline` (one
//! fork per material column, `COL_GARBLE`..`COL_TRIPLE`, with the garble
//! column sub-forked per `GARBLE_CHUNK` instances). **Second one-time
//! re-anchor (triple-column parallelism):** the Beaver-triple column
//! moved from a sequential draw off its column fork to the same
//! chunk-fork discipline as the garble column (one sub-fork of the
//! triple fork per `GARBLE_CHUNK` instances), so triple generation can
//! ride the same dealer threads. The reference below re-derives both
//! schedules independently, so with equal seeds both paths must still
//! produce equal material and therefore equal transcripts; any
//! divergence in the batched data plane shows up as a share or byte
//! mismatch.
//!
//! **Third one-time note (circuit material squeeze):** circuit templates
//! are now CSE-built and `Circuit::optimize`d, so the garbled material
//! is smaller than the seed's. No byte constants live in this file and
//! the RNG schedule draws per *input wire* (never per gate), so both the
//! reference (`spec.build_circuit()`) and the batched path (the memoized
//! `spec.circuit()` template, identical content by construction) shifted
//! together — the equivalence here is unaffected.

use circa::beaver::{self, TripleShare};
use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::field::{random_fp, Fp};
use circa::gc::batch::GARBLE_CHUNK;
use circa::gc::eval::evaluate_with_scratch;
use circa::gc::garble::{garble_with_scratch, GarbledCircuit, InputEncoding};
use circa::ot;
use circa::prf::Label;
use circa::protocol::offline::{
    offline_relu_layer, COL_GARBLE, COL_OT, COL_ROUT, COL_RV, COL_TRIPLE,
};
use circa::protocol::online::online_relu_layer;
use circa::ss::SharePair;
use circa::util::Rng;

/// Per-ReLU material exactly as the seed represented it.
struct RefClient {
    gcs: Vec<GarbledCircuit>,
    client_labels: Vec<Vec<Label>>,
    r_v: Vec<Fp>,
    r_out: Vec<Fp>,
    triples: Vec<TripleShare>,
    offline_bytes: u64,
}

struct RefServer {
    encodings: Vec<InputEncoding>,
    output_decode: Vec<Vec<bool>>,
    triples: Vec<TripleShare>,
}

/// `offline_relu_layer`'s column-wise RNG schedule, re-derived
/// independently over per-ReLU objects: fork the parent once per
/// material column in the documented order, garble chunk `c` of
/// `GARBLE_CHUNK` instances from `garble_fork.fork(c)`, then fill the
/// scalar columns from their own forks.
fn offline_ref(variant: ReluVariant, xc: &[Fp], rng: &mut Rng) -> (RefClient, RefServer) {
    let spec = variant.spec();
    let circuit = spec.build_circuit();
    let mut scratch = Vec::new();
    let mut c = RefClient {
        gcs: Vec::new(),
        client_labels: Vec::new(),
        r_v: Vec::new(),
        r_out: Vec::new(),
        triples: Vec::new(),
        offline_bytes: 0,
    };
    let mut s =
        RefServer { encodings: Vec::new(), output_decode: Vec::new(), triples: Vec::new() };

    let mut rng_garble = rng.fork(COL_GARBLE);
    let mut rng_rv = rng.fork(COL_RV);
    let mut rng_rout = rng.fork(COL_ROUT);
    let _rng_ot = rng.fork(COL_OT);
    let mut rng_triple = rng.fork(COL_TRIPLE);

    // Garble column: per-chunk sub-forks, chunk c = instances
    // [c·GARBLE_CHUNK, (c+1)·GARBLE_CHUNK).
    for (chunk_idx, chunk) in xc.chunks(GARBLE_CHUNK).enumerate() {
        let mut chunk_rng = rng_garble.fork(chunk_idx as u64);
        for _ in chunk {
            let (gc, enc) = garble_with_scratch(&circuit, &mut chunk_rng, &mut scratch);
            c.offline_bytes += gc.table_bytes() as u64;
            s.output_decode.push(gc.output_decode.clone());
            c.gcs.push(gc);
            s.encodings.push(enc);
        }
    }

    // Scalar columns.
    for _ in xc {
        c.r_v.push(random_fp(&mut rng_rv));
    }
    for _ in xc {
        c.r_out.push(random_fp(&mut rng_rout));
    }

    // OT column (no randomness drawn — the fork above reserves the
    // stream).
    for (i, &x) in xc.iter().enumerate() {
        let bits = spec.client_bits(x, c.r_v[i], c.r_out[i]);
        let batch = ot::ot_choose(&s.encodings[i], 0, &bits);
        c.offline_bytes += batch.bytes_on_wire as u64;
        c.client_labels.push(batch.labels);
    }

    // Triple column: chunk-forked exactly like the garble column —
    // chunk c of GARBLE_CHUNK instances draws from rng_triple.fork(c).
    if spec.uses_beaver() {
        for (chunk_idx, chunk) in xc.chunks(GARBLE_CHUNK).enumerate() {
            let mut chunk_rng = rng_triple.fork(chunk_idx as u64);
            for _ in chunk {
                let t = beaver::gen_triple(&mut chunk_rng);
                c.triples.push(t.p1);
                s.triples.push(t.p2);
                c.offline_bytes += 6 * 4;
            }
        }
    }
    (c, s)
}

/// The seed's `online_relu_layer`, reconstructed per-ReLU. Returns
/// (client shares, server shares, bytes_to_client, bytes_to_server).
fn online_ref(
    variant: ReluVariant,
    c: &RefClient,
    s: &RefServer,
    xc: &[Fp],
    xs: &[Fp],
) -> (Vec<Fp>, Vec<Fp>, u64, u64) {
    let spec = variant.spec();
    let circuit = spec.build_circuit();
    let n = xc.len();
    let base = spec.server_input_base();
    let mut to_client = 0u64;
    let mut to_server = 0u64;

    // Round 1: server labels, one Vec per ReLU.
    let all_labels: Vec<Vec<Label>> = (0..n)
        .map(|i| {
            let bits = spec.server_bits(xs[i]);
            bits.iter().enumerate().map(|(j, &b)| s.encodings[i].encode(base + j, b)).collect()
        })
        .collect();
    to_client += all_labels.iter().map(|l: &Vec<Label>| l.len() as u64 * 16).sum::<u64>();

    // Client: per-ReLU evaluation.
    let mut colors: Vec<bool> = Vec::new();
    let mut labels: Vec<Label> = Vec::new();
    let mut scratch: Vec<Label> = Vec::new();
    for i in 0..n {
        labels.clear();
        labels.extend_from_slice(&c.client_labels[i]);
        labels.extend_from_slice(&all_labels[i]);
        let out = evaluate_with_scratch(&circuit, &c.gcs[i], &labels, &mut scratch);
        colors.extend(out.iter().map(|l| l.color()));
    }
    to_server += (colors.len() as u64).div_ceil(8);

    // Server decode.
    let m = spec.n_outputs;
    let server_out: Vec<Fp> = (0..n)
        .map(|i| {
            let bits: Vec<bool> = colors[i * m..(i + 1) * m]
                .iter()
                .zip(&s.output_decode[i])
                .map(|(&cb, &d)| cb ^ d)
                .collect();
            circa::circuits::spec::bits_fp(&bits)
        })
        .collect();

    if !spec.uses_beaver() {
        return (c.r_out.clone(), server_out, to_client, to_server);
    }

    // Beaver round + resharing.
    let mut open_c = Vec::new();
    let mut open_s = Vec::new();
    for i in 0..n {
        let oc = beaver::open(xc[i], c.r_v[i], &c.triples[i]);
        let os = beaver::open(xs[i], server_out[i], &s.triples[i]);
        open_c.push(oc.e);
        open_c.push(oc.f);
        open_s.push(os.e);
        open_s.push(os.f);
    }
    to_server += open_c.len() as u64 * 4;
    to_client += open_s.len() as u64 * 4;

    let mut server_y = Vec::new();
    let mut deltas = Vec::new();
    for i in 0..n {
        let e = open_c[2 * i] + open_s[2 * i];
        let f = open_c[2 * i + 1] + open_s[2 * i + 1];
        let y_c = beaver::mul_share(e, f, &c.triples[i], true);
        server_y.push(beaver::mul_share(e, f, &s.triples[i], false));
        deltas.push(y_c - c.r_out[i]);
    }
    to_server += deltas.len() as u64 * 4;
    for i in 0..n {
        server_y[i] = server_y[i] + deltas[i];
    }
    (c.r_out.clone(), server_y, to_client, to_server)
}

/// Mixed-magnitude signed inputs (both fault regimes represented).
fn sample_inputs(n: usize, rng: &mut Rng) -> Vec<Fp> {
    (0..n)
        .map(|i| {
            let mag = if i % 3 == 0 { rng.below(1 << 6) } else { rng.below(1 << 20) } as i64;
            Fp::from_i64(if rng.bool() { mag } else { -mag })
        })
        .collect()
}

fn assert_equivalent(variant: ReluVariant, seed: u64) {
    let n = 16;
    let mut data_rng = Rng::new(seed);
    let xs_vals = sample_inputs(n, &mut data_rng);
    let shares: Vec<SharePair> =
        xs_vals.iter().map(|&v| SharePair::share(v, &mut data_rng)).collect();
    let xc: Vec<Fp> = shares.iter().map(|s| s.client).collect();
    let xs: Vec<Fp> = shares.iter().map(|s| s.server).collect();

    // Same protocol seed on both paths: material must be bit-identical.
    let mut rng_ref = Rng::new(seed ^ 0xC1CA);
    let (rc, rs) = offline_ref(variant, &xc, &mut rng_ref);
    let (ref_yc, ref_ys, ref_to_client, ref_to_server) = online_ref(variant, &rc, &rs, &xc, &xs);

    let mut rng_batch = Rng::new(seed ^ 0xC1CA);
    let (cm, sm) = offline_relu_layer(variant, &xc, &mut rng_batch);
    let (yc, ys, stats) = online_relu_layer(&cm, &sm, &xc, &xs);

    // Bit-identical offline material (spot check: tables + client labels).
    for i in 0..n {
        assert_eq!(cm.gc.table_of(i), &rc.gcs[i].table[..], "{variant:?}: table {i}");
        assert_eq!(
            cm.client_labels_of(i),
            &rc.client_labels[i][..],
            "{variant:?}: client labels {i}"
        );
    }

    // Bit-identical byte ledgers.
    assert_eq!(cm.offline_bytes, rc.offline_bytes, "{variant:?}: offline bytes");
    assert_eq!(stats.bytes_to_client, ref_to_client, "{variant:?}: online bytes to client");
    assert_eq!(stats.bytes_to_server, ref_to_server, "{variant:?}: online bytes to server");

    // Bit-identical output shares (not just reconstructed values).
    assert_eq!(yc, ref_yc, "{variant:?}: client output shares");
    assert_eq!(ys, ref_ys, "{variant:?}: server output shares");
}

#[test]
fn offline_column_schedule_matches_across_chunk_boundary() {
    // n > GARBLE_CHUNK: the reference's per-chunk sub-forks must line up
    // with garble_chunked's chunk streams, including the ragged tail.
    let variant = ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero };
    let n = GARBLE_CHUNK + 5;
    let mut data_rng = Rng::new(42);
    let xc: Vec<Fp> = (0..n).map(|_| random_fp(&mut data_rng)).collect();

    let (rc, rs) = offline_ref(variant, &xc, &mut Rng::new(0xABCD));
    let (cm, sm) = offline_relu_layer(variant, &xc, &mut Rng::new(0xABCD));

    for i in [0, GARBLE_CHUNK - 1, GARBLE_CHUNK, n - 1] {
        assert_eq!(cm.gc.table_of(i), &rc.gcs[i].table[..], "table {i}");
        assert_eq!(cm.client_labels_of(i), &rc.client_labels[i][..], "labels {i}");
        assert_eq!(sm.encodings.view(i).label0, &rs.encodings[i].label0[..], "label0 {i}");
    }
    assert_eq!(cm.offline_bytes, rc.offline_bytes);
    assert_eq!(cm.r_v, rc.r_v);
    assert_eq!(cm.r_out, rc.r_out);
    // The triple column's chunk sub-forks must line up across the
    // boundary too, value for value (both parties' shares).
    assert_eq!(cm.triples.len(), rc.triples.len());
    for i in [0, GARBLE_CHUNK - 1, GARBLE_CHUNK, n - 1] {
        let (a, b) = (&cm.triples[i], &rc.triples[i]);
        assert_eq!((a.a, a.b, a.ab), (b.a, b.b, b.ab), "client triple {i}");
        let (a, b) = (&sm.triples[i], &rs.triples[i]);
        assert_eq!((a.a, a.b, a.ab), (b.a, b.b, b.ab), "server triple {i}");
    }
}

#[test]
fn batched_path_matches_seed_baseline_relu() {
    assert_equivalent(ReluVariant::BaselineRelu, 101);
}

#[test]
fn batched_path_matches_seed_naive_sign() {
    assert_equivalent(ReluVariant::NaiveSign, 102);
}

#[test]
fn batched_path_matches_seed_stochastic_sign() {
    assert_equivalent(ReluVariant::StochasticSign { mode: FaultMode::PosZero }, 103);
    assert_equivalent(ReluVariant::StochasticSign { mode: FaultMode::NegPass }, 104);
}

#[test]
fn batched_path_matches_seed_truncated_sign_k_sweep() {
    for (i, k) in [0u32, 8, 12].into_iter().enumerate() {
        assert_equivalent(
            ReluVariant::TruncatedSign { k, mode: FaultMode::PosZero },
            200 + i as u64,
        );
        assert_equivalent(
            ReluVariant::TruncatedSign { k, mode: FaultMode::NegPass },
            300 + i as u64,
        );
    }
}

//! Contract tests for the column-wise offline RNG schedule: whole-layer
//! dealing must be **thread-count-invariant** (same seed ⇒ bit-identical
//! material on 1, 2, or 8 threads, for every variant and truncation
//! level), and material shipped by a multi-threaded dealer over the wire
//! must be bit-identical to an inline single-threaded deal from the same
//! RNG stream. Together these are what let a dealer use every core it
//! has without changing a single bit of what it ships.

use circa::circuits::spec::{FaultMode, ReluVariant};
use circa::field::{random_fp, Fp};
use circa::gc::batch::GARBLE_CHUNK;
use circa::protocol::client::ClientLayer;
use circa::protocol::linear::{LinearOp, Matrix};
use circa::protocol::offline::{offline_relu_layer_mt, ClientReluMaterial, ServerReluMaterial};
use circa::protocol::server::{offline_network_mt, NetworkPlan};
use circa::util::Rng;
use circa::wire::dealer::{deal_session_mt, spawn_mem_dealer, RemoteDealer};
use std::sync::Arc;

fn all_variants() -> Vec<ReluVariant> {
    vec![
        ReluVariant::BaselineRelu,
        ReluVariant::NaiveSign,
        ReluVariant::StochasticSign { mode: FaultMode::PosZero },
        ReluVariant::StochasticSign { mode: FaultMode::NegPass },
        ReluVariant::TruncatedSign { k: 0, mode: FaultMode::PosZero },
        ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero },
        ReluVariant::TruncatedSign { k: 12, mode: FaultMode::NegPass },
    ]
}

fn assert_layers_identical(
    tag: &str,
    (ca, sa): &(ClientReluMaterial, ServerReluMaterial),
    (cb, sb): &(ClientReluMaterial, ServerReluMaterial),
) {
    assert_eq!(ca.gc.tables(), cb.gc.tables(), "{tag}: tables");
    assert_eq!(ca.gc.output_decode(), cb.gc.output_decode(), "{tag}: decode");
    assert_eq!(ca.client_labels, cb.client_labels, "{tag}: client labels");
    assert_eq!(ca.r_v, cb.r_v, "{tag}: r_v");
    assert_eq!(ca.r_out, cb.r_out, "{tag}: r_out");
    assert_eq!(ca.offline_bytes, cb.offline_bytes, "{tag}: offline bytes");
    assert_eq!(sa.encodings.label0(), sb.encodings.label0(), "{tag}: label0 arena");
    assert_eq!(
        sa.encodings.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
        sb.encodings.deltas().iter().map(|d| d.0).collect::<Vec<_>>(),
        "{tag}: deltas"
    );
    assert_eq!(sa.output_decode, sb.output_decode, "{tag}: server decode");
    assert_eq!(ca.triples.len(), cb.triples.len(), "{tag}: triple count");
    for (i, (ta, tb)) in ca.triples.iter().zip(&cb.triples).enumerate() {
        assert_eq!((ta.a, ta.b, ta.ab), (tb.a, tb.b, tb.ab), "{tag}: client triple {i}");
    }
    for (i, (ta, tb)) in sa.triples.iter().zip(&sb.triples).enumerate() {
        assert_eq!((ta.a, ta.b, ta.ab), (tb.a, tb.b, tb.ab), "{tag}: server triple {i}");
    }
}

#[test]
fn layer_deal_is_thread_count_invariant_all_variants() {
    // Multi-chunk layer (n > 2·GARBLE_CHUNK, ragged tail) so the chunk →
    // thread-group split actually differs between the thread counts.
    let n = 2 * GARBLE_CHUNK + 37;
    let mut data_rng = Rng::new(0x5EED);
    let xc: Vec<Fp> = (0..n).map(|_| random_fp(&mut data_rng)).collect();
    for (vi, variant) in all_variants().into_iter().enumerate() {
        let seed = 900 + vi as u64;
        let base = offline_relu_layer_mt(variant, &xc, &mut Rng::new(seed), 1);
        for threads in [2, 8] {
            let got = offline_relu_layer_mt(variant, &xc, &mut Rng::new(seed), threads);
            assert_layers_identical(&format!("{variant:?} @ {threads} threads"), &base, &got);
        }
    }
}

#[test]
fn layer_deal_consumes_parent_rng_identically_for_any_thread_count() {
    // The parent RNG must advance by exactly the five column forks
    // whatever the thread count — otherwise material dealt *after* a
    // layer would depend on how the layer was threaded.
    let mut data_rng = Rng::new(3);
    let xc: Vec<Fp> = (0..20).map(|_| random_fp(&mut data_rng)).collect();
    let mut states = Vec::new();
    for threads in [1, 2, 8] {
        let mut rng = Rng::new(1234);
        let _ = offline_relu_layer_mt(
            ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero },
            &xc,
            &mut rng,
            threads,
        );
        states.push(rng.next_u64());
    }
    assert!(states.windows(2).all(|w| w[0] == w[1]), "parent RNG state diverged: {states:?}");
}

#[test]
fn triple_column_is_chunk_forked_and_thread_invariant() {
    // The triple column rides the same chunk-fork discipline as the
    // garble column: one sub-fork of the COL_TRIPLE fork per
    // GARBLE_CHUNK instances, whatever the thread count. Pin both the
    // invariance and the exact schedule (re-derived independently) over
    // a multi-chunk layer with a ragged tail.
    use circa::beaver;
    use circa::protocol::offline::{COL_GARBLE, COL_OT, COL_ROUT, COL_RV, COL_TRIPLE};
    let n = 2 * GARBLE_CHUNK + 37;
    let mut data_rng = Rng::new(0x7719);
    let xc: Vec<Fp> = (0..n).map(|_| random_fp(&mut data_rng)).collect();
    let variant = ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero };
    let seed = 0x7712u64;

    let (c1, s1) = offline_relu_layer_mt(variant, &xc, &mut Rng::new(seed), 1);
    for threads in [2, 8] {
        let (ct, st) = offline_relu_layer_mt(variant, &xc, &mut Rng::new(seed), threads);
        for i in 0..n {
            let (a, b) = (&c1.triples[i], &ct.triples[i]);
            assert_eq!((a.a, a.b, a.ab), (b.a, b.b, b.ab), "{threads}t: client triple {i}");
            let (a, b) = (&s1.triples[i], &st.triples[i]);
            assert_eq!((a.a, a.b, a.ab), (b.a, b.b, b.ab), "{threads}t: server triple {i}");
        }
    }

    // Re-derive the schedule: column forks in documented order, then
    // chunk sub-forks of the triple fork.
    let mut rng = Rng::new(seed);
    let _ = rng.fork(COL_GARBLE);
    let _ = rng.fork(COL_RV);
    let _ = rng.fork(COL_ROUT);
    let _ = rng.fork(COL_OT);
    let mut rng_triple = rng.fork(COL_TRIPLE);
    let mut i = 0usize;
    for chunk_idx in 0..n.div_ceil(GARBLE_CHUNK) {
        let mut chunk_rng = rng_triple.fork(chunk_idx as u64);
        let hi = ((chunk_idx + 1) * GARBLE_CHUNK).min(n);
        while i < hi {
            let t = beaver::gen_triple(&mut chunk_rng);
            let got = &c1.triples[i];
            assert_eq!((got.a, got.b, got.ab), (t.p1.a, t.p1.b, t.p1.ab), "triple {i}");
            let got = &s1.triples[i];
            assert_eq!((got.a, got.b, got.ab), (t.p2.a, t.p2.b, t.p2.ab), "triple {i}");
            i += 1;
        }
    }
}

fn tiny_plan(seed: u64, variant: ReluVariant) -> Arc<NetworkPlan> {
    let mut rng = Rng::new(seed);
    let linears: Vec<Arc<dyn LinearOp>> = vec![
        Arc::new(Matrix::random(5, 6, 20, &mut rng)),
        Arc::new(Matrix::random(4, 5, 20, &mut rng)),
        Arc::new(Matrix::random(3, 4, 20, &mut rng)),
    ];
    Arc::new(NetworkPlan::unscaled(linears, variant))
}

/// Pull the ReLU materials out of a client net, in layer order.
fn relu_layers(layers: &[ClientLayer]) -> Vec<&ClientReluMaterial> {
    layers
        .iter()
        .filter_map(|l| match l {
            ClientLayer::Relu(m) => Some(m.as_ref()),
            ClientLayer::Linear { .. } => None,
        })
        .collect()
}

#[test]
fn network_deal_is_thread_count_invariant() {
    let plan = tiny_plan(7, ReluVariant::TruncatedSign { k: 8, mode: FaultMode::PosZero });
    let (c1, s1, b1) = offline_network_mt(&plan, &mut Rng::new(55), 1);
    for threads in [2, 8] {
        let (ct, st, bt) = offline_network_mt(&plan, &mut Rng::new(55), threads);
        assert_eq!(b1, bt, "{threads} threads: offline bytes");
        assert_eq!(s1.n_relus(), st.n_relus());
        for (i, (a, b)) in relu_layers(&c1.layers).iter().zip(relu_layers(&ct.layers)).enumerate()
        {
            assert_eq!(a.gc.tables(), b.gc.tables(), "{threads} threads: layer {i} tables");
            assert_eq!(a.client_labels, b.client_labels, "{threads} threads: layer {i} labels");
            assert_eq!(a.r_out, b.r_out, "{threads} threads: layer {i} r_out");
        }
    }
}

#[test]
fn dealer_wire_material_matches_inline_deal_bit_for_bit() {
    // A dealer fanning each session across 8 threads, shipped over the
    // wire codec, against a single-threaded inline deal from the same
    // seed: the ReLU material itself (not just the inference transcript)
    // must be identical.
    let plan = tiny_plan(9, ReluVariant::TruncatedSign { k: 12, mode: FaultMode::PosZero });
    let dealer_seed = 0xDEA1;
    let registry = circa::coordinator::ModelRegistry::single(plan.clone(), dealer_seed);
    let fp = registry.fingerprints()[0];
    let (chan, dealer_thread) = spawn_mem_dealer(plan.clone(), dealer_seed, 8);
    let mut dealer = RemoteDealer::connect(chan, registry).expect("handshake");
    let sessions = dealer.fetch(fp, 2).expect("fetch");
    dealer.close();
    dealer_thread.join().unwrap();

    let mut inline_rng = Rng::new(dealer_seed);
    for (si, session) in sessions.iter().enumerate() {
        let inline = deal_session_mt(&plan, &mut inline_rng, 1);
        assert_eq!(session.offline_bytes, inline.offline_bytes, "session {si}: bytes");
        assert_eq!(session.n_relus(), inline.n_relus(), "session {si}: relus");
        let wire = relu_layers(&session.client.layers);
        let local = relu_layers(&inline.client.layers);
        assert_eq!(wire.len(), local.len());
        for (i, (w, l)) in wire.iter().zip(&local).enumerate() {
            assert_eq!(w.gc.tables(), l.gc.tables(), "session {si} layer {i}: tables");
            assert_eq!(
                w.gc.output_decode(),
                l.gc.output_decode(),
                "session {si} layer {i}: decode"
            );
            assert_eq!(w.client_labels, l.client_labels, "session {si} layer {i}: labels");
            assert_eq!(w.r_v, l.r_v, "session {si} layer {i}: r_v");
            assert_eq!(w.r_out, l.r_out, "session {si} layer {i}: r_out");
        }
    }
}

//! A line-aware token stream for Rust source — just enough lexing for
//! the lint rules, with no external parser. Comments and string/char
//! literal *contents* are consumed, never tokenized as code, so a
//! `panic!` inside a doc comment or an error message can never trip a
//! rule. Every token carries its 1-based source line for reporting and
//! for matching against line-scoped waivers.

/// Token kind. The lexer is deliberately coarse: multi-character
/// operators arrive as consecutive [`Tok::Punct`] tokens (`==` is two
/// `=`), which is exactly what the pattern-matching rules want.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`unwrap`, `let`, `unsafe`, ...).
    Ident(String),
    /// Numeric literal as written (`12`, `0xFF`, `1_000u64`).
    Num(String),
    /// Any string literal flavor (`"…"`, `r#"…"#`, `b"…"`).
    Str,
    /// Char or byte-char literal (`'a'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// One punctuation character.
    Punct(char),
}

/// One token plus the line it starts on.
#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
}

impl Token {
    /// The identifier text, if this is an identifier.
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    /// True when this token is the punctuation character `c`.
    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }
}

/// Numeric value of a `Tok::Num`, handling `_` separators, `0x`/`0o`/
/// `0b` prefixes, and trailing type suffixes. `None` for floats or
/// anything else unparseable.
pub fn num_value(text: &str) -> Option<u128> {
    let clean: String = text.chars().filter(|c| *c != '_').collect();
    let (radix, digits) = if let Some(d) = clean.strip_prefix("0x") {
        (16, d)
    } else if let Some(d) = clean.strip_prefix("0o") {
        (8, d)
    } else if let Some(d) = clean.strip_prefix("0b") {
        (2, d)
    } else {
        (10, clean.as_str())
    };
    // Strip a type suffix (`u8`, `usize`, `i64`, ...) if present.
    let end = digits
        .find(|c: char| !c.is_digit(radix))
        .unwrap_or(digits.len());
    let (digits, suffix) = digits.split_at(end);
    if digits.is_empty()
        || !(suffix.is_empty() || suffix.starts_with('u') || suffix.starts_with('i'))
    {
        return None;
    }
    u128::from_str_radix(digits, radix).ok()
}

/// Lex `src` into a token stream.
pub fn lex(src: &str) -> Vec<Token> {
    let lexer = Lexer {
        chars: src.chars().collect(),
        i: 0,
        line: 1,
        toks: Vec::new(),
    };
    lexer.run()
}

struct Lexer {
    chars: Vec<char>,
    i: usize,
    line: usize,
    toks: Vec<Token>,
}

impl Lexer {
    fn run(mut self) -> Vec<Token> {
        while self.i < self.chars.len() {
            let c = self.chars[self.i];
            match c {
                '\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ if c.is_whitespace() => self.i += 1,
                '/' if self.peek(1) == Some('/') => self.skip_line_comment(),
                '/' if self.peek(1) == Some('*') => self.skip_block_comment(),
                '"' => {
                    self.push(Tok::Str);
                    self.i += 1;
                    self.skip_string_body();
                }
                '\'' => self.lifetime_or_char(),
                _ if c.is_alphabetic() || c == '_' => self.word(),
                _ if c.is_ascii_digit() => self.number(),
                _ => {
                    self.push(Tok::Punct(c));
                    self.i += 1;
                }
            }
        }
        self.toks
    }

    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn push(&mut self, tok: Tok) {
        self.toks.push(Token {
            tok,
            line: self.line,
        });
    }

    fn skip_line_comment(&mut self) {
        while self.i < self.chars.len() && self.chars[self.i] != '\n' {
            self.i += 1;
        }
    }

    fn skip_block_comment(&mut self) {
        let mut depth = 1usize;
        self.i += 2;
        while self.i < self.chars.len() && depth > 0 {
            match (self.chars[self.i], self.peek(1)) {
                ('/', Some('*')) => {
                    depth += 1;
                    self.i += 2;
                }
                ('*', Some('/')) => {
                    depth -= 1;
                    self.i += 2;
                }
                ('\n', _) => {
                    self.line += 1;
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
    }

    /// Consume a plain (escaped) string body; `self.i` is at the first
    /// content char.
    fn skip_string_body(&mut self) {
        while self.i < self.chars.len() {
            match self.chars[self.i] {
                '\\' => self.i += 2,
                '"' => {
                    self.i += 1;
                    return;
                }
                '\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
    }

    /// Consume a raw string body; `self.i` is at the first `#` or the
    /// opening quote.
    fn skip_raw_string_body(&mut self) {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.i += 1;
        }
        if self.peek(0) != Some('"') {
            return;
        }
        self.i += 1;
        while self.i < self.chars.len() {
            match self.chars[self.i] {
                '\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                '"' => {
                    let mut matched = 0usize;
                    while matched < hashes && self.peek(1 + matched) == Some('#') {
                        matched += 1;
                    }
                    self.i += 1;
                    if matched == hashes {
                        self.i += hashes;
                        return;
                    }
                }
                _ => self.i += 1,
            }
        }
    }

    /// `'a` (lifetime) vs `'x'` / `'\n'` (char literal).
    fn lifetime_or_char(&mut self) {
        let next = self.peek(1).unwrap_or(' ');
        let is_lifetime = (next.is_alphabetic() || next == '_') && self.peek(2) != Some('\'');
        if is_lifetime {
            self.push(Tok::Lifetime);
            self.i += 1;
            while self.peek(0).is_some_and(|c| c.is_alphanumeric() || c == '_') {
                self.i += 1;
            }
        } else {
            self.push(Tok::Char);
            self.i += 1;
            if self.peek(0) == Some('\\') {
                self.i += 2; // the backslash and the escape head
            } else {
                self.i += 1;
            }
            // Tolerates multi-char escapes (\x41, \u{…}) by scanning to
            // the closing quote.
            while self.i < self.chars.len() && self.chars[self.i] != '\'' {
                if self.chars[self.i] == '\n' {
                    self.line += 1;
                }
                self.i += 1;
            }
            self.i += 1;
        }
    }

    /// Identifier, keyword, or a string/char literal behind a `r`/`b`/
    /// `br` prefix.
    fn word(&mut self) {
        let start = self.i;
        while self.peek(0).is_some_and(|c| c.is_alphanumeric() || c == '_') {
            self.i += 1;
        }
        let word: String = self.chars[start..self.i].iter().collect();
        let next = self.peek(0);
        let raw_string_follows =
            next == Some('"') || (next == Some('#') && self.raw_hashes_then_quote());
        match (word.as_str(), next) {
            ("r" | "br", _) if raw_string_follows => {
                self.push(Tok::Str);
                self.skip_raw_string_body();
            }
            ("b", Some('"')) => {
                self.push(Tok::Str);
                self.i += 1;
                self.skip_string_body();
            }
            ("b", Some('\'')) => {
                // Byte-char literal: reuse the char path past the `b`.
                self.lifetime_or_char();
            }
            ("r", Some('#')) => {
                // Raw identifier `r#ident`.
                self.i += 1;
                let s = self.i;
                while self.peek(0).is_some_and(|c| c.is_alphanumeric() || c == '_') {
                    self.i += 1;
                }
                let ident: String = self.chars[s..self.i].iter().collect();
                self.push(Tok::Ident(ident));
            }
            _ => self.push(Tok::Ident(word)),
        }
    }

    /// After a `#`-prefixed position, do hashes lead to a `"` (raw
    /// string) rather than an identifier (raw ident)?
    fn raw_hashes_then_quote(&self) -> bool {
        let mut k = 0usize;
        while self.peek(k) == Some('#') {
            k += 1;
        }
        k > 0 && self.peek(k) == Some('"')
    }

    fn number(&mut self) {
        let start = self.i;
        while self.peek(0).is_some_and(|c| c.is_alphanumeric() || c == '_') {
            self.i += 1;
        }
        // Fractional part — only when a digit follows the dot, so `0..n`
        // ranges and `1.max(x)` method calls stay separate tokens.
        if self.peek(0) == Some('.') && self.peek(1).is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
            while self.peek(0).is_some_and(|c| c.is_alphanumeric() || c == '_') {
                self.i += 1;
            }
        }
        let text: String = self.chars[start..self.i].iter().collect();
        self.push(Tok::Num(text));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_code() {
        let src = r##"
            // unwrap() in a comment
            /* panic! in /* a nested */ block */
            let s = "unwrap() in a string";
            let r = r#"expect( in a raw string"#;
            let b = b"assert! bytes";
            real_ident();
        "##;
        let ids = idents(src);
        let banned = ["unwrap", "panic", "expect"];
        assert!(!ids.iter().any(|s| banned.contains(&s.as_str())));
        assert!(ids.iter().any(|s| s == "real_ident"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lifetimes = toks.iter().filter(|t| t.tok == Tok::Lifetime).count();
        let chars = toks.iter().filter(|t| t.tok == Tok::Char).count();
        assert_eq!((lifetimes, chars), (2, 1));
    }

    #[test]
    fn lines_track_through_multiline_constructs() {
        let src = "let a = \"x\ny\";\nlet b = 1; /* c\nc */ let d = 2;";
        let toks = lex(src);
        let line_of = |name: &str| {
            let tok = toks.iter().find(|t| t.ident() == Some(name));
            tok.map(|t| t.line)
        };
        assert_eq!(line_of("b"), Some(3));
        assert_eq!(line_of("d"), Some(4));
    }

    #[test]
    fn num_values_parse() {
        assert_eq!(num_value("12"), Some(12));
        assert_eq!(num_value("0xFF"), Some(255));
        assert_eq!(num_value("1_000u64"), Some(1000));
        assert_eq!(num_value("0b1010"), Some(10));
        assert_eq!(num_value("1.5"), None);
    }

    #[test]
    fn ranges_and_floats_disambiguate() {
        let toks = lex("a[0..n]; let x = 1.5;");
        assert!(toks.iter().any(|t| t.tok == Tok::Num("1.5".into())));
        assert!(toks.iter().any(|t| t.tok == Tok::Num("0".into())));
    }
}

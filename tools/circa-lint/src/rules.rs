//! The five lint rules, run over the token stream of one file.
//!
//! Rule IDs (used in findings and `lint:allow` waivers):
//!
//! * `r1` — decode-no-panic: no `unwrap`/`expect`/panicking macro/bare
//!   indexing in untrusted-input modules.
//! * `r2` — lock discipline: no blocking call while a `.lock()` guard
//!   is live in the hot coordinator/reactor/dealer modules.
//! * `r3` — unsafe audit: `unsafe` only in the allowlist, and always
//!   with an adjacent `// SAFETY:` comment.
//! * `r4` — wire-constant drift: discriminant uniqueness, decode-arm
//!   coverage, and compared-not-just-written MAGIC/VERSION consts.
//! * `r5` — length-cast safety: no truncating `as` cast on
//!   length-derived values in decode modules.
//!
//! See `docs/INVARIANTS.md` for the full statements and waiver policy.

use crate::lexer::{lex, num_value, Tok, Token};

/// Modules whose non-test code handles untrusted bytes (rules r1 + r5).
pub const R1_MODULES: &[&str] = &[
    "wire/codec.rs",
    "wire/frame.rs",
    "wire/auth.rs",
    "net/proto.rs",
    "net/frames.rs",
    "util/bytes.rs",
    // Not a decode path, but held to the same no-panic bar: the
    // process-wide template cache sits under every serving-tier deal,
    // and a poisoned or panicking lookup would take the dealer down.
    "circuits/template.rs",
];

/// Modules whose `.lock()` scopes must stay free of blocking calls.
pub const R2_MODULES: &[&str] = &[
    "coordinator/pool.rs",
    "coordinator/service.rs",
    "net/reactor.rs",
    "wire/dealer.rs",
];

/// The only files allowed to contain `unsafe` at all.
pub const R3_ALLOWLIST: &[&str] = &["prf/backend.rs"];

/// Repo-wide budget for `lint:allow` waivers (enforced by the CLI).
pub const MAX_WAIVERS: usize = 5;

/// One reported violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let Finding { file, line, rule, message } = self;
        write!(f, "{file}:{line} {rule} {message}")
    }
}

/// A `// lint:allow(rule): reason` comment. A full-line waiver covers
/// itself, any directly following comment lines, and the first code
/// line after them; a trailing waiver covers its own line only.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: String,
    pub line: usize,
    pub last_covered: usize,
    pub reason_empty: bool,
}

/// Everything the engine learned about one file.
#[derive(Debug, Default)]
pub struct Report {
    /// Violations not covered by any waiver — these fail the build.
    pub findings: Vec<Finding>,
    /// Violations silenced by a waiver (still counted and printed).
    pub waived: Vec<Finding>,
    /// All waivers present in the file, matched or not.
    pub waivers: Vec<Waiver>,
}

fn in_set(path: &str, set: &[&str]) -> bool {
    set.iter().any(|m| path.ends_with(m))
}

fn r4_applies(path: &str) -> bool {
    path.contains("wire/") || path.ends_with("net/proto.rs")
}

/// Run every applicable rule over `src`, reported under `path` (repo-
/// relative, `/`-separated — the suffix decides which rules apply).
pub fn check_source(path: &str, src: &str) -> Report {
    let norm = path.replace('\\', "/");
    let toks = lex(src);
    let lines: Vec<&str> = src.lines().collect();
    let waivers = parse_waivers(&lines);
    let in_test = test_mask(&toks);
    let mut found = Vec::new();
    if in_set(&norm, R1_MODULES) {
        r1_no_panic(&norm, &toks, &in_test, &mut found);
        r5_length_casts(&norm, &toks, &in_test, &mut found);
    }
    if in_set(&norm, R2_MODULES) {
        r2_lock_discipline(&norm, &toks, &in_test, &mut found);
    }
    r3_unsafe_audit(&norm, &toks, &lines, &mut found);
    if r4_applies(&norm) {
        r4_wire_constants(&norm, &toks, &mut found);
    }
    found.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    let mut report = Report {
        waivers,
        ..Report::default()
    };
    for f in found {
        let waived = report
            .waivers
            .iter()
            .any(|w| w.rule == f.rule && f.line >= w.line && f.line <= w.last_covered);
        if waived {
            report.waived.push(f);
        } else {
            report.findings.push(f);
        }
    }
    report
}

/// Parse `lint:allow` waivers out of the raw comment text. The marker
/// must start the comment (`// lint:allow(r1): reason`), so prose that
/// merely *mentions* the syntax never creates a waiver.
fn parse_waivers(lines: &[&str]) -> Vec<Waiver> {
    let mut out = Vec::new();
    for (idx, raw) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let Some(slash) = raw.find("//") else { continue };
        let text = raw[slash + 2..].trim_start_matches(['/', '!']).trim_start();
        let Some(rest) = text.strip_prefix("lint:allow(") else { continue };
        let Some(close) = rest.find(')') else { continue };
        let rule = rest[..close].trim().to_ascii_lowercase();
        let reason = rest[close + 1..].strip_prefix(':').map(str::trim).unwrap_or("");
        let has_code_before = !raw[..slash].trim().is_empty();
        let last_covered = if has_code_before {
            lineno
        } else {
            // Skip the rest of the comment block, cover the first code
            // line after it.
            let mut j = lineno; // 0-based index of the next line
            while j < lines.len() && lines[j].trim_start().starts_with("//") {
                j += 1;
            }
            if j < lines.len() {
                j + 1
            } else {
                lines.len()
            }
        };
        out.push(Waiver {
            rule,
            line: lineno,
            last_covered,
            reason_empty: reason.is_empty(),
        });
    }
    out
}

/// Token mask: `true` where the token sits inside a `#[cfg(test)]` or
/// `#[test]` item (attribute through the matching close brace).
fn test_mask(toks: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0;
    while i < toks.len() {
        if !test_attr_at(toks, i) {
            i += 1;
            continue;
        }
        // Find the item's opening brace (a `;` first means no body).
        let mut j = i;
        let mut open = None;
        while j < toks.len() {
            match &toks[j].tok {
                Tok::Punct('{') => {
                    open = Some(j);
                    break;
                }
                Tok::Punct(';') => break,
                _ => {}
            }
            j += 1;
        }
        let Some(open) = open else {
            i = j + 1;
            continue;
        };
        let mut depth = 0usize;
        let mut k = open;
        while k < toks.len() {
            match &toks[k].tok {
                Tok::Punct('{') => depth += 1,
                Tok::Punct('}') => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        let end = k.min(toks.len() - 1);
        for m in mask.iter_mut().take(end + 1).skip(i) {
            *m = true;
        }
        i = end + 1;
    }
    mask
}

/// Does `#[cfg(test)]` or `#[test]` start at token `i`?
fn test_attr_at(toks: &[Token], i: usize) -> bool {
    if !toks[i].is_punct('#') || !toks.get(i + 1).is_some_and(|t| t.is_punct('[')) {
        return false;
    }
    match toks.get(i + 2).and_then(Token::ident) {
        Some("test") => toks.get(i + 3).is_some_and(|t| t.is_punct(']')),
        Some("cfg") => {
            toks.get(i + 3).is_some_and(|t| t.is_punct('('))
                && toks.get(i + 4).and_then(Token::ident) == Some("test")
        }
        _ => false,
    }
}

// ---------------------------------------------------------------- r1

const R1_BANNED_CALLS: &[&str] = &[
    "unwrap",
    "unwrap_err",
    "unwrap_unchecked",
    "expect",
    "expect_err",
];

const R1_BANNED_MACROS: &[&str] = &[
    "panic",
    "unreachable",
    "todo",
    "unimplemented",
    "assert",
    "assert_eq",
    "assert_ne",
    "debug_assert",
    "debug_assert_eq",
    "debug_assert_ne",
];

/// Keywords that can directly precede a `[` without it being indexing
/// (patterns, array types, array literals). Space-separated word list.
const NON_EXPR_KEYWORDS: &str =
    "let mut ref in if else match return break continue as move while loop for impl dyn fn pub use const static struct enum mod unsafe where crate super type trait await box";

/// Does the token end an expression, so that a following `[` indexes it?
fn ends_expr(t: &Token) -> bool {
    match &t.tok {
        Tok::Ident(w) => !NON_EXPR_KEYWORDS.split_whitespace().any(|k| k == w),
        Tok::Num(_) | Tok::Str => true,
        Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('?') => true,
        _ => false,
    }
}

fn r1_no_panic(file: &str, toks: &[Token], in_test: &[bool], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        match &t.tok {
            Tok::Ident(w) => {
                let next = |c| toks.get(i + 1).is_some_and(|t: &Token| t.is_punct(c));
                let after_dot = i > 0 && toks[i - 1].is_punct('.');
                if R1_BANNED_CALLS.contains(&w.as_str()) && next('(') && after_dot {
                    out.push(Finding {
                        file: file.into(),
                        line: t.line,
                        rule: "r1",
                        message: format!(
                            "`.{w}()` can panic on untrusted input; propagate an error instead"
                        ),
                    });
                } else if R1_BANNED_MACROS.contains(&w.as_str()) && next('!') {
                    out.push(Finding {
                        file: file.into(),
                        line: t.line,
                        rule: "r1",
                        message: format!("`{w}!` is forbidden in decode paths; return an error"),
                    });
                }
            }
            Tok::Punct('[') if i > 0 && ends_expr(&toks[i - 1]) => {
                out.push(Finding {
                    file: file.into(),
                    line: t.line,
                    rule: "r1",
                    message: "bare indexing/slicing can panic; use `.get(..)` and an error".into(),
                });
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------- r2

const R2_BLOCKING: &[&str] = &[
    "read",
    "write",
    "read_exact",
    "write_all",
    "read_to_end",
    "recv",
    "recv_timeout",
    "connect",
    "sleep",
    "accept",
    "join",
];

/// Lock-free atomic RMW ops — *not* blocking, despite the `fetch`
/// prefix that catches fences like `fetch_material`.
const ATOMIC_RMW: &[&str] = &[
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_or",
    "fetch_xor",
    "fetch_nand",
    "fetch_min",
    "fetch_max",
    "fetch_update",
];

fn is_blocking_call(w: &str) -> bool {
    R2_BLOCKING.contains(&w) || (w.starts_with("fetch") && !ATOMIC_RMW.contains(&w))
}

fn r2_lock_discipline(file: &str, toks: &[Token], in_test: &[bool], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        if in_test[i]
            || toks[i].ident() != Some("lock")
            || i == 0
            || !toks[i - 1].is_punct('.')
            || !toks.get(i + 1).is_some_and(|t| t.is_punct('('))
        {
            continue;
        }
        let guard_line = toks[i].line;
        let binder = let_binder(toks, i);
        let end = match &binder {
            Some(b) => let_scope_end(toks, i, b),
            None => temporary_scope_end(toks, i),
        };
        let mut j = i + 2; // past `lock (`
        while j < end {
            if let Some(w) = toks[j].ident() {
                if !in_test[j]
                    && is_blocking_call(w)
                    && toks.get(j + 1).is_some_and(|t| t.is_punct('('))
                {
                    out.push(Finding {
                        file: file.into(),
                        line: toks[j].line,
                        rule: "r2",
                        message: format!(
                            "blocking `{w}()` while the `.lock()` guard from line {guard_line} \
                             is live; drop the guard first"
                        ),
                    });
                }
            }
            j += 1;
        }
    }
}

/// If the statement containing token `i` is a `let` (or `if let`/
/// `while let`) binding, the name the guard is bound to. `None` for
/// statement temporaries and for the discarded `_` binding.
fn let_binder(toks: &[Token], i: usize) -> Option<String> {
    let mut s = i;
    while s > 0 {
        match &toks[s - 1].tok {
            Tok::Punct(';') | Tok::Punct('{') | Tok::Punct('}') => break,
            _ => s -= 1,
        }
    }
    let starts_let = toks[s].ident() == Some("let")
        || (matches!(toks[s].ident(), Some("if") | Some("while"))
            && toks.get(s + 1).and_then(Token::ident) == Some("let"));
    if !starts_let {
        return None;
    }
    // Last identifier before the (single) `=`: covers `let mut g`,
    // `let Ok(g)`, and `if let Ok(mut g)` alike.
    let mut binder = None;
    let mut k = s;
    while k < i {
        if toks[k].is_punct('=') && !toks.get(k + 1).is_some_and(|t| t.is_punct('=')) {
            break;
        }
        if let Some(w) = toks[k].ident() {
            if !matches!(w, "let" | "mut" | "if" | "while" | "ref") {
                binder = Some(w.to_string());
            }
        }
        k += 1;
    }
    binder.filter(|b| b.as_str() != "_")
}

/// Scope end (exclusive token index) for a guard bound by `let`: the
/// first `drop(binder)` after the lock, or the close of the enclosing
/// block.
fn let_scope_end(toks: &[Token], i: usize, binder: &str) -> usize {
    let mut depth = 0i32;
    let mut j = i + 1;
    while j < toks.len() {
        match &toks[j].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                if depth < 0 {
                    return j;
                }
            }
            Tok::Ident(w)
                if w == "drop"
                    && toks.get(j + 1).is_some_and(|t| t.is_punct('('))
                    && toks.get(j + 2).and_then(Token::ident) == Some(binder)
                    && toks.get(j + 3).is_some_and(|t| t.is_punct(')')) =>
            {
                return j;
            }
            _ => {}
        }
        j += 1;
    }
    toks.len()
}

/// Scope end for a guard that is a statement temporary: the statement's
/// `;`, extended through the body when the statement is a block header
/// (`if let Ok(g) = x.lock() { … }`).
fn temporary_scope_end(toks: &[Token], i: usize) -> usize {
    let mut depth = 0i32;
    let mut j = i + 1;
    while j < toks.len() {
        match &toks[j].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                if depth <= 0 {
                    return j;
                }
            }
            Tok::Punct(';') if depth == 0 => return j,
            _ => {}
        }
        j += 1;
    }
    toks.len()
}

// ---------------------------------------------------------------- r3

fn r3_unsafe_audit(file: &str, toks: &[Token], lines: &[&str], out: &mut Vec<Finding>) {
    for t in toks {
        if t.ident() != Some("unsafe") {
            continue;
        }
        if !in_set(file, R3_ALLOWLIST) {
            out.push(Finding {
                file: file.into(),
                line: t.line,
                rule: "r3",
                message: format!(
                    "`unsafe` outside the audited allowlist ({})",
                    R3_ALLOWLIST.join(", ")
                ),
            });
        }
        if !has_safety_comment(lines, t.line) {
            out.push(Finding {
                file: file.into(),
                line: t.line,
                rule: "r3",
                message: "`unsafe` without an adjacent `// SAFETY:` comment".into(),
            });
        }
    }
}

/// A `SAFETY:` marker on the same line, or anywhere in the contiguous
/// comment/attribute block directly above it (bounded look-back).
fn has_safety_comment(lines: &[&str], line: usize) -> bool {
    if lines.get(line - 1).is_some_and(|s| s.contains("SAFETY:")) {
        return true;
    }
    let mut j = line - 1; // 0-based index of the line above
    let mut looked = 0;
    while j >= 1 && looked < 12 {
        let s = lines[j - 1].trim_start();
        if !(s.starts_with("//") || s.starts_with("#[") || s.starts_with("#!")) {
            return false;
        }
        if s.contains("SAFETY:") {
            return true;
        }
        j -= 1;
        looked += 1;
    }
    false
}

// ---------------------------------------------------------------- r4

/// Known u8 tag-constant namespaces (per-prefix value uniqueness +
/// decode-use required).
const R4_TAG_PREFIXES: &[&str] = &["MODE_", "LAYER_", "REQ_", "KIND_"];

#[derive(Debug)]
struct ConstDecl {
    name: String,
    ty: Option<String>,
    value: Option<u128>,
    line: usize,
    /// Token index of the name in its declaration (excluded from uses).
    name_idx: usize,
}

fn r4_wire_constants(file: &str, toks: &[Token], out: &mut Vec<Finding>) {
    r4_enums(file, toks, out);
    let consts = collect_consts(toks);
    for c in &consts {
        if (c.name.contains("MAGIC") || c.name.contains("VERSION"))
            && !has_comparison_use(toks, &c.name, c.name_idx)
        {
            out.push(Finding {
                file: file.into(),
                line: c.line,
                rule: "r4",
                message: format!(
                    "`{}` is never compared on a decode path — wire preambles must be \
                     checked, not just written",
                    c.name
                ),
            });
        }
    }
    // u8 tag namespaces: value uniqueness per prefix + decode use.
    for prefix in R4_TAG_PREFIXES {
        let group: Vec<&ConstDecl> = consts
            .iter()
            .filter(|c| c.name.starts_with(prefix) && c.ty.as_deref() == Some("u8"))
            .collect();
        for (a, b) in pairs(&group) {
            if a.value.is_some() && a.value == b.value {
                out.push(Finding {
                    file: file.into(),
                    line: b.line,
                    rule: "r4",
                    message: format!(
                        "tag `{}` duplicates the value of `{}` in the `{prefix}*` namespace",
                        b.name, a.name
                    ),
                });
            }
        }
        for c in &group {
            if !has_decode_use(toks, &c.name, c.name_idx) {
                out.push(Finding {
                    file: file.into(),
                    line: c.line,
                    rule: "r4",
                    message: format!(
                        "tag `{}` has no decode use (match arm or comparison) — encode and \
                         decode have drifted",
                        c.name
                    ),
                });
            }
        }
    }
}

fn pairs<'a, T>(xs: &'a [&'a T]) -> Vec<(&'a T, &'a T)> {
    let mut out = Vec::new();
    for (i, a) in xs.iter().enumerate() {
        for b in xs.iter().skip(i + 1) {
            out.push((*a, *b));
        }
    }
    out
}

/// Enum discriminant uniqueness + `from_u8` decode-arm coverage.
fn r4_enums(file: &str, toks: &[Token], out: &mut Vec<Finding>) {
    let from_u8_body = fn_body_range(toks, "from_u8");
    let mut i = 0;
    while i < toks.len() {
        if toks[i].ident() != Some("enum") {
            i += 1;
            continue;
        }
        let name = toks.get(i + 1).and_then(Token::ident).unwrap_or("?").to_string();
        let Some(open) = find_punct(toks, i, '{') else {
            i += 1;
            continue;
        };
        let close = match_brace(toks, open);
        // Variants with explicit discriminants at body depth 1:
        // `Ident = <num>` where the `=` is not `==`.
        let mut variants: Vec<(String, u128, usize)> = Vec::new();
        let mut depth = 0i32;
        for j in open..close {
            match &toks[j].tok {
                Tok::Punct('{') => depth += 1,
                Tok::Punct('}') => depth -= 1,
                Tok::Ident(v) if depth == 1 => {
                    if toks.get(j + 1).is_some_and(|t| t.is_punct('='))
                        && !toks.get(j + 2).is_some_and(|t| t.is_punct('='))
                    {
                        if let Some(Tok::Num(n)) = toks.get(j + 2).map(|t| &t.tok) {
                            if let Some(val) = num_value(n) {
                                variants.push((v.clone(), val, toks[j].line));
                            }
                        }
                    }
                }
                _ => {}
            }
        }
        for (a, b) in pairs(&variants.iter().collect::<Vec<_>>()) {
            if a.1 == b.1 {
                out.push(Finding {
                    file: file.into(),
                    line: b.2,
                    rule: "r4",
                    message: format!(
                        "enum {name}: variants {} and {} share discriminant {}",
                        a.0, b.0, a.1
                    ),
                });
            }
        }
        if let Some((fs, fe)) = from_u8_body {
            if !variants.is_empty() {
                for (v, val, line) in &variants {
                    if !arm_covers(toks, fs, fe, *val, v) {
                        out.push(Finding {
                            file: file.into(),
                            line: *line,
                            rule: "r4",
                            message: format!(
                                "enum {name}: variant {v} (= {val}) has no matching decode \
                                 arm in from_u8"
                            ),
                        });
                    }
                }
            }
        }
        i = close + 1;
    }
}

/// Is there a `val => … Variant` arm inside the token range?
fn arm_covers(toks: &[Token], fs: usize, fe: usize, val: u128, variant: &str) -> bool {
    for j in fs..fe {
        let Tok::Num(n) = &toks[j].tok else { continue };
        if num_value(n) != Some(val)
            || !toks.get(j + 1).is_some_and(|t| t.is_punct('='))
            || !toks.get(j + 2).is_some_and(|t| t.is_punct('>'))
        {
            continue;
        }
        let arm_end = (j + 12).min(fe);
        if toks[j + 3..arm_end].iter().any(|t| t.ident() == Some(variant)) {
            return true;
        }
    }
    false
}

/// Token range (exclusive of the closing brace) of `fn <name>`'s body.
fn fn_body_range(toks: &[Token], name: &str) -> Option<(usize, usize)> {
    for i in 0..toks.len() {
        if toks[i].ident() == Some("fn") && toks.get(i + 1).and_then(Token::ident) == Some(name) {
            let open = find_punct(toks, i, '{')?;
            return Some((open, match_brace(toks, open)));
        }
    }
    None
}

fn find_punct(toks: &[Token], from: usize, c: char) -> Option<usize> {
    (from..toks.len()).find(|&j| toks[j].is_punct(c))
}

/// Index of the `}` matching the `{` at `open` (or the last token).
fn match_brace(toks: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    for j in open..toks.len() {
        match &toks[j].tok {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
    }
    toks.len().saturating_sub(1)
}

fn collect_consts(toks: &[Token]) -> Vec<ConstDecl> {
    let mut out = Vec::new();
    let mut i = 0;
    while i + 2 < toks.len() {
        if toks[i].ident() == Some("const") && toks.get(i + 2).is_some_and(|t| t.is_punct(':')) {
            if let Some(name) = toks.get(i + 1).and_then(Token::ident) {
                // ALL_CAPS names only (skips `const fn`, generics).
                if name.chars().all(|c| c.is_ascii_uppercase() || c == '_' || c.is_ascii_digit()) {
                    let ty = toks.get(i + 3).and_then(Token::ident).map(str::to_string);
                    // First numeric literal after the `=`, if any.
                    let mut value = None;
                    let mut j = i + 3;
                    while j < toks.len() && !toks[j].is_punct(';') {
                        if toks[j].is_punct('=') {
                            if let Some(Tok::Num(n)) = toks.get(j + 1).map(|t| &t.tok) {
                                value = num_value(n);
                            }
                            break;
                        }
                        j += 1;
                    }
                    out.push(ConstDecl {
                        name: name.to_string(),
                        ty,
                        value,
                        line: toks[i].line,
                        name_idx: i + 1,
                    });
                }
            }
        }
        i += 1;
    }
    out
}

/// Is `name` used in an `==`/`!=` comparison anywhere besides its
/// declaration?
fn has_comparison_use(toks: &[Token], name: &str, decl_idx: usize) -> bool {
    occurrences(toks, name, decl_idx).any(|i| adjacent_comparison(toks, i))
}

/// Is `name` used as a match arm or in a comparison besides its
/// declaration? (Test-code uses count: coverage is coverage.)
fn has_decode_use(toks: &[Token], name: &str, decl_idx: usize) -> bool {
    occurrences(toks, name, decl_idx).any(|i| {
        adjacent_comparison(toks, i)
            || (toks.get(i + 1).is_some_and(|t| t.is_punct('='))
                && toks.get(i + 2).is_some_and(|t| t.is_punct('>')))
    })
}

fn occurrences<'a>(
    toks: &'a [Token],
    name: &'a str,
    decl_idx: usize,
) -> impl Iterator<Item = usize> + 'a {
    (0..toks.len()).filter(move |&i| i != decl_idx && toks[i].ident() == Some(name))
}

/// `== NAME`, `NAME ==`, `!= NAME`, or `NAME !=` at token `i`.
fn adjacent_comparison(toks: &[Token], i: usize) -> bool {
    let before = i >= 2
        && toks[i - 1].is_punct('=')
        && (toks[i - 2].is_punct('=') || toks[i - 2].is_punct('!'));
    let after = toks.get(i + 1).is_some_and(|t| t.is_punct('=') || t.is_punct('!'))
        && toks.get(i + 2).is_some_and(|t| t.is_punct('='));
    before || after
}

// ---------------------------------------------------------------- r5

const R5_CAST_TARGETS: &[&str] = &["u8", "u16", "u32", "usize"];

/// Punctuation that ends the backward walk over the cast's source
/// expression (statement/operator boundaries).
const R5_STOPS: &str = ";{},=<>+-*/|&^!:";

fn r5_length_casts(file: &str, toks: &[Token], in_test: &[bool], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        if in_test[i] || toks[i].ident() != Some("as") {
            continue;
        }
        let Some(target) = toks.get(i + 1).and_then(Token::ident) else { continue };
        if !R5_CAST_TARGETS.contains(&target) {
            continue;
        }
        if let Some(marker) = length_marker_backward(toks, i) {
            out.push(Finding {
                file: file.into(),
                line: toks[i].line,
                rule: "r5",
                message: format!(
                    "truncating `as {target}` on length-derived `{marker}`; use \
                     `try_from`/checked conversion"
                ),
            });
        }
    }
}

/// Walk the cast's source expression backward looking for a length-ish
/// marker: `.len()`, `.u64()`, anything containing `stride`, or a
/// `*_len` identifier.
fn length_marker_backward(toks: &[Token], cast_idx: usize) -> Option<String> {
    let mut depth = 0i32;
    let mut j = cast_idx;
    let mut steps = 0;
    while j > 0 && steps < 24 {
        j -= 1;
        steps += 1;
        match &toks[j].tok {
            Tok::Punct(')') | Tok::Punct(']') => depth += 1,
            Tok::Punct('(') | Tok::Punct('[') => {
                depth -= 1;
                if depth < 0 {
                    return None;
                }
            }
            Tok::Punct(c) if depth == 0 && R5_STOPS.contains(*c) => return None,
            Tok::Ident(w) => {
                let after_dot = j > 0 && toks[j - 1].is_punct('.');
                if ((w == "len" || w == "u64") && after_dot)
                    || w.contains("stride")
                    || w.ends_with("_len")
                {
                    return Some(w.clone());
                }
                if w == "return" || w == "let" {
                    return None;
                }
            }
            _ => {}
        }
    }
    None
}

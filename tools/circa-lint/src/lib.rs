//! circa-lint: repo-native static analysis for the circa workspace.
//!
//! Five rules, one purpose — keep the properties the test suite cannot
//! cheaply express from regressing silently:
//!
//! * **r1 decode-no-panic** — modules that parse untrusted bytes
//!   ([`rules::R1_MODULES`]) must not contain `unwrap`/`expect`,
//!   panicking macros, or bare indexing outside `#[cfg(test)]`.
//! * **r2 lock discipline** — hot-path modules ([`rules::R2_MODULES`])
//!   must not hold a `.lock()` guard across a blocking call.
//! * **r3 unsafe audit** — `unsafe` only in [`rules::R3_ALLOWLIST`],
//!   always with an adjacent `// SAFETY:` comment.
//! * **r4 wire-constant drift** — message-type discriminants stay
//!   unique and decode-covered; MAGIC/VERSION preambles are compared,
//!   not just written.
//! * **r5 length-cast safety** — no truncating `as` casts on
//!   length-derived values in decode modules.
//!
//! Findings print as `file:line rule message`. A finding can be waived
//! in place with `// lint:allow(rule): reason` — the reason is
//! mandatory and the repo-wide budget is [`rules::MAX_WAIVERS`].
//! Policy and rationale live in `docs/INVARIANTS.md`.

pub mod lexer;
pub mod rules;

pub use rules::{check_source, Finding, Report, Waiver, MAX_WAIVERS};

//! CLI: `circa-lint check [repo-root]` walks `rust/src/**/*.rs`, runs
//! every rule, prints findings as `file:line rule message`, and exits
//! nonzero when any unwaived finding (or waiver-policy violation)
//! remains. CI runs this as a blocking job.

use circa_lint::{check_source, MAX_WAIVERS};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("check") => check(args.get(1).map(String::as_str).unwrap_or(".")),
        _ => {
            eprintln!("usage: circa-lint check [repo-root]");
            ExitCode::from(2)
        }
    }
}

fn check(root: &str) -> ExitCode {
    let root = Path::new(root);
    let src_root = root.join("rust").join("src");
    if !src_root.is_dir() {
        eprintln!(
            "circa-lint: {} has no rust/src — run from the repo root",
            root.display()
        );
        return ExitCode::from(2);
    }
    let mut files = Vec::new();
    if let Err(e) = collect_rs_files(&src_root, &mut files) {
        eprintln!("circa-lint: walking {}: {e}", src_root.display());
        return ExitCode::from(2);
    }
    files.sort();
    let mut failures = 0usize;
    let mut waived = 0usize;
    let mut waivers = 0usize;
    for path in &files {
        let src = match std::fs::read_to_string(path) {
            Ok(src) => src,
            Err(e) => {
                eprintln!("circa-lint: reading {}: {e}", path.display());
                failures += 1;
                continue;
            }
        };
        let rel = path.strip_prefix(root).unwrap_or(path);
        let rel = rel.display().to_string().replace('\\', "/");
        let report = check_source(&rel, &src);
        for f in &report.findings {
            println!("{f}");
            failures += 1;
        }
        for f in &report.waived {
            println!("{f} [waived]");
            waived += 1;
        }
        for w in &report.waivers {
            waivers += 1;
            if w.reason_empty {
                println!(
                    "{rel}:{} waiver `lint:allow({})` has no reason — every waiver must say why",
                    w.line, w.rule
                );
                failures += 1;
            }
        }
    }
    if waivers > MAX_WAIVERS {
        println!("waiver budget exceeded: {waivers} in tree, budget {MAX_WAIVERS}");
        failures += 1;
    }
    println!(
        "circa-lint: {} files checked, {failures} failure(s), {waived} waived, \
         {waivers}/{MAX_WAIVERS} waivers",
        files.len()
    );
    if failures == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

// Lock usage the rule must accept: guards dropped before blocking,
// block-scoped guards, and atomic RMW ops that only share the `fetch`
// prefix with blocking fences.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct Pool {
    state: Arc<Mutex<Vec<u64>>>,
    drops: AtomicU64,
}

impl Pool {
    /// Guard explicitly dropped before the sleep.
    pub fn refill(&self) {
        let mut state = self.state.lock().unwrap();
        state.push(1);
        drop(state);
        std::thread::sleep(Duration::from_millis(10));
    }

    /// Guard confined to an inner block; recv happens after it closes.
    pub fn drain(&self, rx: &std::sync::mpsc::Receiver<u64>) {
        let pending = {
            let state = self.state.lock().unwrap();
            state.len()
        };
        if pending == 0 {
            let _ = rx.recv_timeout(Duration::from_millis(5));
        }
    }

    /// Atomic fetch_add under the lock is not a blocking call.
    pub fn count(&self) {
        let mut state = self.state.lock().unwrap();
        self.drops.fetch_add(1, Ordering::Relaxed);
        state.push(self.drops.load(Ordering::Relaxed));
    }
}

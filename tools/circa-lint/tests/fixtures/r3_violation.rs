// Seeded r3 violations: an unsafe block with no SAFETY comment, in a
// module that is not on the unsafe allowlist.

pub fn transmute_len(v: &[u32]) -> usize {
    let p = v.as_ptr();
    unsafe { p.add(v.len()).offset_from(p) as usize }
}

// Seeded r2 violations: guards held across blocking calls, in each
// binding shape the rule understands.

use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct Pool {
    state: Arc<Mutex<Vec<u64>>>,
}

impl Pool {
    /// let-bound guard, blocking sleep before it is dropped.
    pub fn refill_sleepy(&self) {
        let mut state = self.state.lock().unwrap();
        state.push(1);
        std::thread::sleep(Duration::from_millis(10));
        state.push(2);
    }

    /// Statement-temporary guard inside an `if let` header: the guard
    /// lives for the whole body, including the recv.
    pub fn drain(&self, rx: &std::sync::mpsc::Receiver<u64>) {
        if let Ok(mut state) = self.state.lock() {
            if let Ok(v) = rx.recv() {
                state.push(v);
            }
        }
    }

    /// Guard live across a socket connect.
    pub fn dial(&self, addr: &str) -> std::io::Result<()> {
        let state = self.state.lock().unwrap();
        let _stream = std::net::TcpStream::connect(addr)?;
        drop(state);
        Ok(())
    }
}

// Seeded r1 violations: every panic avenue the rule must catch, plus a
// test module whose identical code must NOT be flagged.

pub fn decode(bytes: &[u8]) -> u32 {
    let first = bytes[0];
    let tail = &bytes[1..5];
    let word: [u8; 4] = tail.try_into().unwrap();
    let n = u32::from_le_bytes(word);
    if n == 0 {
        panic!("zero length");
    }
    assert!(first != 0xFF);
    n + first as u32
}

pub fn lookup(map: &std::collections::HashMap<u32, u32>, k: u32) -> u32 {
    *map.get(&k).expect("key must exist")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_code_may_unwrap_and_index() {
        let v = vec![1u8, 2, 3, 4, 5];
        assert_eq!(decode(&v), u32::from_le_bytes([2, 3, 4, 5]) + 1);
        let x = v[0];
        assert_eq!(Some(x).unwrap(), 1);
        panic!("even this is fine in tests");
    }
}

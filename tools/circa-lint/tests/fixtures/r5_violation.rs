// Seeded r5 violations: truncating casts on length-derived values.

pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
}

pub fn encode(payload: &[u8], out_stride: u64, w: &mut Writer) {
    w.u32(payload.len() as u32);
    w.u32(out_stride as u32);
    let body_len: u64 = 9;
    w.u32(body_len as u32);
}

// Seeded r4 violations: duplicate discriminants, a variant without a
// decode arm, a MAGIC that is written but never compared, and a tag
// namespace with a value collision.

pub const MAGIC: u32 = 0x43495243;

pub const REQ_ALPHA: u8 = 0;
pub const REQ_BETA: u8 = 0;

pub enum MsgType {
    Hello = 1,
    Data = 2,
    Bye = 2,
    Probe = 4,
}

impl MsgType {
    pub fn from_u8(v: u8) -> Result<MsgType, String> {
        match v {
            1 => Ok(MsgType::Hello),
            2 => Ok(MsgType::Data),
            other => Err(format!("unknown message type {other}")),
        }
    }
}

pub fn encode(kind: u8) -> Vec<u8> {
    let mut out = vec![MAGIC as u8];
    match kind {
        REQ_ALPHA => out.push(1),
        REQ_BETA => out.push(2),
        _ => {}
    }
    out
}

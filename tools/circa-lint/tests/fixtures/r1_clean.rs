// Clean decode code: every access is checked, every failure is an
// error value. The rule must stay silent here.

pub fn decode(bytes: &[u8]) -> Result<u32, String> {
    let first = bytes.first().copied().ok_or("empty input")?;
    let tail = bytes.get(1..5).ok_or("short input")?;
    let mut word = [0u8; 4];
    for (o, &x) in word.iter_mut().zip(tail) {
        *o = x;
    }
    let n = u32::from_le_bytes(word);
    if n == 0 {
        return Err("zero length".to_string());
    }
    // Allowed: unwrap_or and friends never panic.
    let fallback = bytes.get(9).copied().unwrap_or(0);
    Ok(n + first as u32 + fallback as u32)
}

pub fn pattern_brackets(bytes: &[u8]) -> u8 {
    // `[` in patterns, types, and literals is not indexing.
    let arr: [u8; 2] = [1, 2];
    if let [a, b] = bytes {
        return a ^ b;
    }
    match bytes {
        [x, ..] => *x,
        [] => arr.iter().sum(),
    }
}

// Clean r5 usage: checked conversions for length-derived values, plus
// casts the rule must leave alone (widening, non-length sources).

pub fn encode(payload: &[u8]) -> Result<Vec<u8>, String> {
    let mut out = Vec::new();
    let len32 = u32::try_from(payload.len()).map_err(|_| "len overflows u32".to_string())?;
    out.extend_from_slice(&len32.to_le_bytes());
    Ok(out)
}

pub fn widen(len_bytes: &[u8; 4], flags: u8) -> u64 {
    // Widening a fixed 4-byte field and a flag byte is not truncation.
    let word = u32::from_le_bytes(*len_bytes) as u64;
    word + flags as u64 + (len_bytes.len() as u64)
}

// Clean r3 usage (checked under the allowlisted prf/backend.rs path):
// every unsafe site carries an adjacent SAFETY comment.

pub fn first_block(v: &[u128]) -> u128 {
    // SAFETY: `v` is non-empty by the caller's contract and the pointer
    // is derived from a live slice borrow.
    unsafe { *v.as_ptr() }
}

#[target_feature(enable = "aes")]
// SAFETY: callers must verify the `aes` cpuid bit before dispatching
// here; the only call site is feature-gated.
unsafe fn kernel(blocks: &mut [u128]) {
    for b in blocks {
        *b ^= 1;
    }
}

pub fn run(blocks: &mut [u128]) {
    // SAFETY: guarded by the same cpuid check the dispatcher performs.
    unsafe { kernel(blocks) }
}

// Clean r4 file: unique discriminants, full decode coverage, compared
// preamble constants, and a healthy tag namespace.

pub const MAGIC: u32 = 0x43495243;
pub const VERSION: u16 = 2;

pub const REQ_ALPHA: u8 = 0;
pub const REQ_BETA: u8 = 1;

pub enum MsgType {
    Hello = 1,
    Data = 2,
    Bye = 3,
}

impl MsgType {
    pub fn from_u8(v: u8) -> Result<MsgType, String> {
        match v {
            1 => Ok(MsgType::Hello),
            2 => Ok(MsgType::Data),
            3 => Ok(MsgType::Bye),
            other => Err(format!("unknown message type {other}")),
        }
    }
}

pub fn decode_preamble(magic: u32, version: u16, kind: u8) -> Result<u8, String> {
    if magic != MAGIC {
        return Err("bad magic".to_string());
    }
    if version != VERSION {
        return Err("bad version".to_string());
    }
    match kind {
        REQ_ALPHA => Ok(0),
        REQ_BETA => Ok(1),
        other => Err(format!("unknown request kind {other}")),
    }
}

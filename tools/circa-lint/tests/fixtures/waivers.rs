// Waiver semantics: a matching waiver silences its finding (and is
// counted), a waiver for a different rule does not, and a waiver
// without a reason is itself a policy violation.

pub fn const_table() -> [u8; 4] {
    let mut table = [0u8; 4];
    let mut i = 0;
    while i < 4 {
        // lint:allow(r1): bounded by the loop condition — an index here
        // can never exceed the fixed table size.
        table[i] = i as u8;
        i += 1;
    }
    table
}

pub fn wrong_rule_waiver(bytes: &[u8]) -> u8 {
    // lint:allow(r5): this waiver names the wrong rule for the line.
    bytes[0]
}

pub fn no_reason(bytes: &[u8]) -> u8 {
    // lint:allow(r1):
    bytes[1]
}

//! Fixture tests: every rule fires on its seeded violation file, stays
//! silent on the clean twin, and waivers silence exactly what they
//! cover. Fixtures live under `tests/fixtures/` and are checked under
//! *virtual* repo paths, since the module path decides which rules apply.

use circa_lint::{check_source, Report};

fn rules_of(report: &Report) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.rule).collect()
}

fn only_rule(report: &Report, rule: &str) -> bool {
    report.findings.iter().all(|f| f.rule == rule)
}

fn has_msg(report: &Report, needle: &str) -> bool {
    report.findings.iter().any(|f| f.message.contains(needle))
}

#[test]
fn r1_fires_on_seeded_violations() {
    let report = check_source(
        "rust/src/wire/codec.rs",
        include_str!("fixtures/r1_violation.rs"),
    );
    assert!(only_rule(&report, "r1"), "{:?}", report.findings);
    // Indexing, slicing, unwrap, panic!, assert!, expect.
    assert_eq!(rules_of(&report).len(), 6, "{:?}", report.findings);
}

#[test]
fn r1_ignores_test_code() {
    let src = include_str!("fixtures/r1_violation.rs");
    let report = check_source("rust/src/wire/codec.rs", src);
    // The #[cfg(test)] module repeats every violation; none of its
    // lines may be reported.
    let test_mod = src.lines().position(|l| l.contains("#[cfg(test)]"));
    let test_mod_line = test_mod.expect("fixture has a test module") + 1;
    assert!(
        report.findings.iter().all(|f| f.line < test_mod_line),
        "test-module lines were flagged: {:?}",
        report.findings
    );
}

#[test]
fn r1_silent_on_clean_code() {
    let report = check_source(
        "rust/src/wire/codec.rs",
        include_str!("fixtures/r1_clean.rs"),
    );
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn r1_does_not_apply_outside_decode_modules() {
    let report = check_source(
        "rust/src/protocol/relu.rs",
        include_str!("fixtures/r1_violation.rs"),
    );
    assert!(
        report.findings.iter().all(|f| f.rule != "r1"),
        "r1 must be scoped to decode modules: {:?}",
        report.findings
    );
}

#[test]
fn r2_fires_on_guard_across_blocking_calls() {
    let report = check_source(
        "rust/src/coordinator/pool.rs",
        include_str!("fixtures/r2_violation.rs"),
    );
    assert!(only_rule(&report, "r2"), "{:?}", report.findings);
    // sleep in refill_sleepy, recv in drain, connect in dial.
    assert_eq!(rules_of(&report).len(), 3, "{:?}", report.findings);
    assert!(has_msg(&report, "`sleep()`"));
    assert!(has_msg(&report, "`recv()`"));
    assert!(has_msg(&report, "`connect()`"));
}

#[test]
fn r2_silent_on_disciplined_locks() {
    let report = check_source(
        "rust/src/coordinator/pool.rs",
        include_str!("fixtures/r2_clean.rs"),
    );
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn r3_fires_outside_allowlist_and_without_safety() {
    let report = check_source(
        "rust/src/gc/table.rs",
        include_str!("fixtures/r3_violation.rs"),
    );
    assert_eq!(rules_of(&report), vec!["r3", "r3"], "{:?}", report.findings);
}

#[test]
fn r3_silent_on_documented_allowlisted_unsafe() {
    let report = check_source(
        "rust/src/prf/backend.rs",
        include_str!("fixtures/r3_clean.rs"),
    );
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn r4_fires_on_constant_drift() {
    let report = check_source(
        "rust/src/wire/frame.rs",
        include_str!("fixtures/r4_violation.rs"),
    );
    assert!(only_rule(&report, "r4"), "{:?}", report.findings);
    assert!(has_msg(&report, "share discriminant"));
    assert!(has_msg(&report, "no matching decode arm"));
    assert!(has_msg(&report, "never compared"));
    assert!(has_msg(&report, "duplicates the value"));
}

#[test]
fn r4_silent_on_consistent_constants() {
    let report = check_source(
        "rust/src/wire/frame.rs",
        include_str!("fixtures/r4_clean.rs"),
    );
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn r5_fires_on_truncating_length_casts() {
    let report = check_source(
        "rust/src/wire/codec.rs",
        include_str!("fixtures/r5_violation.rs"),
    );
    assert_eq!(rules_of(&report), vec!["r5", "r5", "r5"], "{:?}", report.findings);
}

#[test]
fn r5_silent_on_checked_and_widening_conversions() {
    let report = check_source(
        "rust/src/wire/codec.rs",
        include_str!("fixtures/r5_clean.rs"),
    );
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn waivers_silence_exactly_what_they_cover() {
    let report = check_source(
        "rust/src/wire/codec.rs",
        include_str!("fixtures/waivers.rs"),
    );
    // Matching waivers absorb the table indexing and the no-reason
    // indexing; the wrong-rule waiver absorbs nothing.
    assert_eq!(report.waived.len(), 2, "waived: {:?}", report.waived);
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    assert_eq!(report.findings[0].rule, "r1");
    assert_eq!(report.waivers.len(), 3);
    let no_reason = report.waivers.iter().filter(|w| w.reason_empty).count();
    assert_eq!(no_reason, 1, "exactly one waiver is missing its reason");
}

#[test]
fn findings_format_as_file_line_rule_message() {
    let report = check_source(
        "rust/src/wire/codec.rs",
        include_str!("fixtures/r5_violation.rs"),
    );
    let first = report.findings.first().expect("fixture has findings");
    let line = first.to_string();
    assert!(
        line.starts_with("rust/src/wire/codec.rs:") && line.contains(" r5 "),
        "{line}"
    );
}

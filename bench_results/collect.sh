#!/usr/bin/env bash
# Run the bench suite and copy its JSON artifacts into bench_results/
# for tracking. Usage:
#   ./bench_results/collect.sh              # all benches
#   ./bench_results/collect.sh dealer_fleet # one bench
set -euo pipefail

repo="$(cd "$(dirname "$0")/.." && pwd)"
cd "$repo/rust"

if [ $# -ge 1 ]; then
    benches=("$@")
else
    # Every registered bench without a required feature gate.
    benches=(fig3 fig5 table1 table2 table3 ablation layer_batch
             online_batch wire_codec prf_throughput net_serving
             dealer_fleet)
fi

for b in "${benches[@]}"; do
    echo "=== bench: $b ==="
    cargo bench --bench "$b"
done

mkdir -p "$repo/bench_results"
cp -v bench_out/BENCH_*.json "$repo/bench_results/"
echo "done: artifacts in bench_results/ — commit them with your change."
